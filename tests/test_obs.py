"""repro.obs — span tracer, Chrome-trace export, telemetry registry
(ISSUE 7).

Pins the observability contracts: request-tree completeness
(``validate_request_trees``), Chrome trace-event schema validity
(``validate_chrome_trace``), counter/gauge/histogram semantics with label
sets and Prometheus text exposition, the per-request flame decomposition
summing to end-to-end modeled latency, and — the zero-overhead guarantee —
that an untraced server allocates NO object from ``repro.obs`` on its hot
dispatch path while producing the exact same modeled totals and
bit-identical outputs as its traced twin.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (APU, EGPU_16T, CommandQueue, Context, Device,
                        Kernel, NDRange, Stage)
from repro.kernels.gemm.ref import counts as gemm_counts
from repro.kernels.gemm.ref import gemm_ref
from repro.obs import (Gauge, Histogram, MetricsRegistry, Span,
                       TERMINAL_SPANS, Tracer, validate_chrome_trace)
from repro.serve import Server
from repro.serve.server import DECOMP_PHASES

NDR = NDRange((8, 8), (4, 4))


class VClock:
    """Manually-advanced virtual clock for deterministic serve sessions."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _mm_stages(d=8, seed=0, n=2):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((d, d)) * 0.2, jnp.float32)

    def mlp(x, w):
        return jnp.maximum(gemm_ref(x, w), 0.0)

    kern = Kernel("mlp", executor=mlp,
                  counts=lambda **kw: gemm_counts(m=d, n=d, k=d))
    return [Stage(kern, consts=(w,), n_inputs=1) for _ in range(n)]


def _traced_session(n=6, tracer=None, clk=None):
    clk = clk or VClock()
    stages = _mm_stages()
    srv = Server(stages, workers=(EGPU_16T,), bucket_sizes=(8,),
                 max_batch=2, clock=clk, tracer=tracer)
    rng = np.random.default_rng(3)
    rids = []
    for i in range(n):
        clk.t = 0.01 * i
        x = jnp.asarray(rng.standard_normal((4, 8)), jnp.float32)
        rids.append((srv.submit(x), x))
    clk.t = 0.01 * n + 0.1
    srv.flush()
    return srv, stages, rids


# ---------------------------------------------------------------------------
# Tracer core
# ---------------------------------------------------------------------------
def test_span_basics_and_explicit_parent_links():
    tr = Tracer()
    root = tr.begin("request", 1.0, track="rid:7", rid=7, priority=0)
    child = tr.span("execute", 1.5, 2.5, track="rid:7", parent=root, rid=7)
    tr.event(root, 2.0, "retry", lane="0:x")
    assert root.open and not child.open
    assert child.parent_id == root.span_id
    assert child.duration_s == pytest.approx(1.0)
    assert tr.children(root) == [child]
    tr.end(root, 3.0)
    assert root.duration_s == pytest.approx(2.0)
    with pytest.raises(RuntimeError, match="already ended"):
        tr.end(root, 4.0)
    with pytest.raises(ValueError, match="before start"):
        tr.span("bad", 2.0, 1.0)


def test_request_tree_lifecycle_and_validation():
    tr = Tracer()
    tr.begin_request(0, 0.0, priority=1)
    tr.request_event(0, 0.5, "dispatch-pick", lane="0:x")
    tr.child(0, "bucket-wait", 0.0, 0.5)
    tr.finish_request(0, 1.0, "result")
    assert tr.validate_request_trees() == []
    root = tr.request_root(0)
    names = [s.name for s in tr.children(root)]
    assert "admission" in names and names.count("result") == 1
    # events on a finished rid are silently dropped (late bookkeeping)
    tr.request_event(0, 2.0, "retry")
    assert not any(n == "retry" for (_, n, _) in root.events)
    # double-open is loud; double-finish is idempotent-safe
    with pytest.raises(RuntimeError, match="already has a root"):
        tr.begin_request(0, 0.0)
    assert tr.finish_request(0, 9.9, "result") is None
    with pytest.raises(ValueError, match="terminal"):
        tr.finish_request(1, 0.0, "oops")


def test_validator_flags_dangling_and_multi_terminal_trees():
    tr = Tracer()
    tr.begin_request(3, 0.0)
    errs = tr.validate_request_trees()
    assert any("dangling" in e for e in errs)
    assert any("terminal" in e for e in errs)
    # a shed terminal closes it cleanly
    tr.finish_request(3, 0.4, "shed", reason="deadline")
    assert tr.validate_request_trees() == []
    assert set(TERMINAL_SPANS) == {"result", "shed"}


# ---------------------------------------------------------------------------
# Chrome export
# ---------------------------------------------------------------------------
def test_chrome_export_schema_and_track_layout(tmp_path):
    tr = Tracer()
    tr.begin_request(2, 0.0)
    tr.child(2, "execute", 0.2, 0.9)
    tr.finish_request(2, 1.0, "result")
    tr.span("launch", 0.1, 0.9, track="lane:0:e-gpu-16t", n_requests=2)
    tr.instant("lane:0:e-gpu-16t", 1.0, "retire", n_requests=2)
    tr.instant("server", 0.05, "shed-at-door", reason="queue-full")
    path = tmp_path / "trace.json"
    doc = tr.to_chrome_json(path)
    assert validate_chrome_trace(doc) == []
    assert path.exists()
    import json
    assert validate_chrome_trace(json.loads(path.read_text())) == []
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    names = {(e["name"], e["args"]["name"]) for e in meta}
    assert ("process_name", "requests") in names
    assert ("process_name", "lanes") in names
    assert ("thread_name", "rid:2") in names
    # rid tracks keep the rid as tid, under the requests pid
    rid_rows = [e for e in evs if e.get("cat") == "rid:2" and e["ph"] == "X"]
    assert rid_rows and all(e["pid"] == 1 and e["tid"] == 2
                            for e in rid_rows)
    # ts/dur are microseconds of virtual time
    execute = next(e for e in rid_rows if e["name"] == "execute")
    assert execute["ts"] == pytest.approx(0.2e6)
    assert execute["dur"] == pytest.approx(0.7e6)


def test_chrome_validator_catches_orphans_and_non_monotonic_ts():
    assert validate_chrome_trace({}) != []
    assert validate_chrome_trace({"traceEvents": 3}) != []
    bad_orphan = {"traceEvents": [
        {"ph": "X", "name": "a", "pid": 1, "tid": 1, "ts": 0.0, "dur": 1.0,
         "args": {"span_id": 1, "parent_id": 999}}]}
    assert any("orphan" in e for e in validate_chrome_trace(bad_orphan))
    bad_ts = {"traceEvents": [
        {"ph": "X", "name": "a", "pid": 1, "tid": 1, "ts": 5.0, "dur": 1.0},
        {"ph": "X", "name": "b", "pid": 1, "tid": 1, "ts": 4.0, "dur": 1.0}]}
    assert any("monotonic" in e for e in validate_chrome_trace(bad_ts))
    bad_dur = {"traceEvents": [
        {"ph": "X", "name": "a", "pid": 1, "tid": 1, "ts": 0.0, "dur": -1.0}]}
    assert any("negative dur" in e for e in validate_chrome_trace(bad_dur))


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------
def test_counter_inc_set_total_and_labels():
    reg = MetricsRegistry()
    c = reg.counter("repro_test_total", "help text")
    c.inc(lane="a")
    c.inc(2.0, lane="a")
    c.inc(lane="b")
    assert c.value(lane="a") == 3.0 and c.value(lane="b") == 1.0
    with pytest.raises(ValueError, match="only go up"):
        c.inc(-1.0)
    # snapshot-publisher style: idempotent, loud on decrease
    c.set_total(5.0, lane="a")
    c.set_total(5.0, lane="a")
    assert c.value(lane="a") == 5.0
    with pytest.raises(ValueError, match="cannot decrease"):
        c.set_total(4.0, lane="a")
    with pytest.raises(ValueError, match="invalid metric name"):
        reg.counter("bad name!")
    with pytest.raises(ValueError, match="invalid label name"):
        c.inc(**{"bad-label": 1})


def test_gauge_and_histogram_semantics():
    g = Gauge("g")
    g.set(2.5, lane="x")
    g.inc(0.5, lane="x")
    assert g.value(lane="x") == 3.0
    h = Histogram("h", buckets=(0.001, 0.01, 0.1, 1.0))
    for v in (0.0005, 0.005, 0.005, 0.05, 0.5):
        h.observe(v)
    snap = h.value()
    assert snap["count"] == 5 and snap["sum"] == pytest.approx(0.5605)
    assert snap["buckets"][0.01] == 3          # cumulative
    assert h.quantile(0.5) == 0.01             # bucket upper bound
    assert h.quantile(1.0) == 0.5              # clamped to observed max
    with pytest.raises(ValueError):
        Histogram("h2", buckets=())


def test_registry_get_or_create_and_type_clash():
    reg = MetricsRegistry()
    a = reg.counter("x_total")
    assert reg.counter("x_total") is a
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total")
    assert reg.get("x_total") is a and reg.get("nope") is None


def test_prometheus_text_exposition():
    reg = MetricsRegistry()
    reg.counter("c_total", "a counter").set_total(3, lane="a")
    reg.gauge("g").set(1.5)
    h = reg.histogram("h", "a histogram", buckets=(0.1, 1.0))
    h.observe(0.05, lane="z")
    txt = reg.to_prometheus_text()
    assert "# HELP c_total a counter" in txt
    assert "# TYPE c_total counter" in txt
    assert 'c_total{lane="a"} 3.0' in txt
    assert "g 1.5" in txt
    assert 'h_bucket{lane="z",le="0.1"} 1' in txt
    assert 'h_bucket{lane="z",le="+Inf"} 1' in txt
    assert 'h_sum{lane="z"} 0.05' in txt
    assert 'h_count{lane="z"} 1' in txt
    snap = reg.snapshot()
    assert snap["c_total"]["type"] == "counter"
    assert snap["c_total"]["samples"][0]["labels"] == {"lane": "a"}


# ---------------------------------------------------------------------------
# Traced server end-to-end
# ---------------------------------------------------------------------------
def test_traced_server_emits_complete_request_trees():
    tr = Tracer()
    srv, stages, rids = _traced_session(tracer=tr)
    assert sorted(rid for rid, _ in rids) == tr.request_rids()
    assert tr.validate_request_trees() == []
    for rid, _ in rids:
        root = tr.request_root(rid)
        names = [s.name for s in tr.children(root)]
        for expected in ("admission", "bucket-wait", "dispatch", "execute",
                         "result"):
            assert expected in names, (rid, names)
        evs = [n for (_, n, _) in root.events]
        assert "submit" in evs and "dispatch-pick" in evs
        # phase children tile [t_submit, t_done] contiguously
        by = {s.name: s for s in tr.children(root)}
        assert by["bucket-wait"].t0 == root.t0
        assert by["dispatch"].t0 == by["bucket-wait"].t1
        assert by["execute"].t0 == by["dispatch"].t1
        assert by["execute"].t1 == root.t1
    # the first micro-batch misses the graph cache, later ones hit
    all_evs = [n for rid, _ in rids
               for (_, n, _) in tr.request_root(rid).events]
    assert "cache-miss" in all_evs and "cache-hit" in all_evs
    # lane track: one launch slice per batch, with kernel slices under it
    launches = [s for s in tr.spans if s.name == "launch"]
    assert len(launches) == 3            # 6 requests / max_batch 2
    kid_names = {s.name for launch in launches
                 for s in tr.children(launch)}
    assert "startup+scheduling" in kid_names and "mlp" in kid_names
    doc = srv.tracer.to_chrome_json()
    assert validate_chrome_trace(doc) == []


def test_traced_and_untraced_twins_agree_bit_identically():
    srv_t, stages, rids_t = _traced_session(tracer=Tracer())
    srv_u, _, rids_u = _traced_session(tracer=None)
    rt, ru = srv_t.report(), srv_u.report()
    assert rt.n_requests == ru.n_requests
    assert rt.modeled_latency_s == ru.modeled_latency_s
    assert rt.goodput_per_s_modeled == ru.goodput_per_s_modeled
    assert (rt.modeled_energy_per_request_j
            == ru.modeled_energy_per_request_j)
    for (rid_t, x), (rid_u, _) in zip(rids_t, rids_u):
        (a,) = srv_t.result(rid_t)
        (b,) = srv_u.result(rid_u)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        ref, _ = APU(EGPU_16T).offload(stages, (x,), mode="eager")
        np.testing.assert_array_equal(np.asarray(a),
                                      np.asarray(ref[0].data))


def test_untraced_server_allocates_no_obs_objects(monkeypatch):
    """The zero-overhead-when-off guarantee: with tracer=None the hot
    path must never construct a Span (or any tracer state)."""
    def boom(*a, **kw):
        raise AssertionError("repro.obs.Span allocated on untraced path")

    monkeypatch.setattr(Span, "__init__", boom)
    srv, stages, rids = _traced_session(tracer=None)
    for rid, _ in rids:
        assert len(srv.result(rid)) == 1
    assert srv.report().n_requests == len(rids)


def test_flame_decomposition_sums_to_end_to_end_latency():
    tr = Tracer()
    srv, _, rids = _traced_session(tracer=tr)
    rep = srv.report()
    decomp = rep.latency_decomposition_s
    assert set(decomp) == set(DECOMP_PHASES)
    for phase, pcts in decomp.items():
        assert set(pcts) == {50, 99}
    # per-request: the five phase children of each tree tile submit->done,
    # so summing the phase series must reproduce the end-to-end latency
    for rid, _ in rids:
        root = tr.request_root(rid)
        by = {s.name: s for s in tr.children(root)}
        phases = (by["admission"].duration_s + by["bucket-wait"].duration_s
                  + by["dispatch"].duration_s + by["execute"].duration_s)
        assert phases == pytest.approx(root.t1 - root.t0)
    lines = rep.summary().splitlines()
    flame = [ln for ln in lines if ln.startswith("flame")]
    assert len(flame) == 2
    assert all(phase in flame[0] for phase in DECOMP_PHASES)


def test_server_publish_metrics_covers_the_stack():
    srv, _, rids = _traced_session(tracer=None)
    reg = srv.publish_metrics()
    assert isinstance(reg, MetricsRegistry)
    c = reg.get("repro_serve_requests_total")
    assert c is not None and c.value() == len(rids)
    assert reg.get("repro_graph_cache_events_total").value(kind="misses") == 1
    lane = reg.get("repro_lane_requests_total")
    assert lane is not None
    (key,) = lane.labels()
    assert dict(key)["lane"] == "0:e-gpu-16t"
    # idempotent re-publish into the same registry (snapshot style)
    assert srv.publish_metrics(reg) is reg
    assert c.value() == len(rids)
    txt = reg.to_prometheus_text()
    assert "repro_serve_latency_phase_seconds" in txt
    assert 'quantile="p50"' in txt


# ---------------------------------------------------------------------------
# CommandQueue tracing + released-event metadata (satellite)
# ---------------------------------------------------------------------------
def _mm_kernel(d=8, seed=0):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((d, d)) * 0.2, jnp.float32)

    def mlp(x):
        return jnp.maximum(gemm_ref(x, w), 0.0)

    return Kernel("mlp", executor=mlp,
                  counts=lambda **kw: gemm_counts(m=d, n=d, k=d))


def test_command_queue_traces_modeled_kernel_spans():
    tr = Tracer()
    ctx = Context(Device(EGPU_16T))
    q = CommandQueue(ctx, tracer=tr)
    x = jnp.ones((8, 8), jnp.float32)
    e1 = q.enqueue_nd_range(_mm_kernel(), NDR, (ctx.create_buffer(x),))
    e2 = q.enqueue_nd_range(_mm_kernel(seed=1), NDR, (e1.outputs[0],))
    q.finish()
    spans = [s for s in tr.spans if s.track.startswith("queue:")]
    assert [s.name for s in spans] == ["mlp", "mlp"]
    # laid end-to-end on the queue's cumulative modeled timeline
    assert spans[0].t0 == 0.0
    assert spans[0].duration_s == pytest.approx(e1.modeled.total_s)
    assert spans[1].t0 == pytest.approx(e1.modeled.total_s)
    assert spans[1].duration_s == pytest.approx(e2.modeled.total_s)
    assert validate_chrome_trace(tr.to_chrome_json()) == []


def test_released_event_metadata_survives_profiling_window():
    """Pins the released-event contract ``Event.wall_s`` documents: release
    drops the functional outputs (wait() is loud) while the O(1) cost
    metadata — dispatch_s/wall_s, modeled, energy_j — stays readable."""
    ctx = Context(Device(EGPU_16T))
    q = CommandQueue(ctx, max_events=1)   # bounded profiling window
    x = jnp.ones((8, 8), jnp.float32)
    kern = _mm_kernel()
    events = [q.enqueue_nd_range(kern, NDR, (ctx.create_buffer(x),))
              for _ in range(3)]
    q.finish()
    released = [e for e in events if e.released]
    assert len(released) == 2            # window kept only the newest
    for ev in released:
        assert ev.wall_s == ev.dispatch_s >= 0.0
        assert ev.modeled is not None and ev.modeled.total_s > 0.0
        assert ev.energy_j is not None and ev.energy_j > 0.0
        assert ev.outputs == ()
        with pytest.raises(RuntimeError, match="released"):
            ev.wait()
    # window totals stay exact regardless of the release
    assert q.total_modeled_s() == pytest.approx(
        sum(e.modeled.total_s for e in events))
