"""Validate the reproduction against the paper's own published claims.

Anchors (paper abstract, §VIII, Figs 2-4) vs our analytic models calibrated
on the TinyBio workload (repro.apps.tinybio.TINYBIO_WORKLOAD):

  area            0.24 .. 0.38 mm²   (1.6x .. 2.5x host's 0.15 mm²)
  leakage         130.13 .. 305.32 uW (4.4x .. 10.3x host's 29.50 uW)
  total power     <= 28 mW @ 300 MHz / 0.8 V (16T)
  scheduling      ~25 us constant; < 1 % of GeMM 256x256 runtime
  transfer        stabilizes ≈ 20 % of GeMM runtime
  TinyBio         speed-up 3.4x .. 14.3x (per-stage 3.1 .. 15.1)
                  energy reduction 1.7x .. 3.1x

Each claim is asserted within the tolerance noted inline (model vs silicon;
our analytic model hits every endpoint within ±15 %).
"""

import dataclasses

import pytest

from repro.apps.tinybio import TINYBIO_WORKLOAD
from repro.core import (EGPU_4T, EGPU_8T, EGPU_16T, HOST, characterize,
                        egpu_active_power_mw, egpu_energy_j, egpu_time,
                        host_energy_j, host_time)
from repro.core.scheduler import optimal_ndrange
from repro.kernels.delineate.ref import counts as del_counts
from repro.kernels.fir.ref import counts as fir_counts
from repro.kernels.gemm.ref import counts as gemm_counts
from repro.kernels.stockham_fft.ref import counts as fft_counts
from repro.kernels.svm.ref import counts as svm_counts

CONFIGS = (EGPU_4T, EGPU_8T, EGPU_16T)


# ---------------------------------------------------------------------------
# Fig 2: static characterization
# ---------------------------------------------------------------------------
def test_area_matches_paper():
    areas = [characterize(c).total_area_mm2 for c in CONFIGS]
    assert areas == sorted(areas)
    assert abs(areas[0] - 0.24) / 0.24 < 0.05
    assert abs(areas[-1] - 0.38) / 0.38 < 0.05
    overh = [characterize(c).area_overhead for c in CONFIGS]
    assert 1.5 <= overh[0] <= 1.7 and 2.4 <= overh[-1] <= 2.6


def test_leakage_matches_paper():
    leaks = [characterize(c).total_leak_uw for c in CONFIGS]
    assert abs(leaks[0] - 130.13) / 130.13 < 0.05
    assert abs(leaks[-1] - 305.32) / 305.32 < 0.05
    overh = [characterize(c).leak_overhead for c in CONFIGS]
    assert 4.1 <= overh[0] <= 4.7 and 9.8 <= overh[-1] <= 10.9


def test_host_anchors():
    h = characterize(HOST)
    assert h.total_area_mm2 == pytest.approx(0.15)
    assert h.total_leak_uw == pytest.approx(29.50)


def test_power_budget_28mw():
    """Abstract: the 16T system operates within a 28 mW power budget."""
    for c in CONFIGS:
        assert egpu_active_power_mw(c) <= 28.0
    assert egpu_active_power_mw(EGPU_16T) >= 20.0   # ... and is not trivial


# ---------------------------------------------------------------------------
# Fig 3: GeMM overheads
# ---------------------------------------------------------------------------
def _gemm_phases(cfg, size):
    c = gemm_counts(size, size, size)
    ndr = optimal_ndrange(size * size, cfg)
    return egpu_time(cfg, c, ndr)


def test_scheduling_constant_25us():
    """Scheduling is ~25 us and does not grow with matrix size (paper
    §VIII-B: work-items == hardware threads)."""
    for cfg in CONFIGS:
        scheds = []
        for size in (32, 64, 128, 256):
            t = _gemm_phases(cfg, size)
            scheds.append((t.startup + t.scheduling) / cfg.freq_hz)
        assert max(scheds) - min(scheds) < 1e-9          # constant
        assert 15e-6 < scheds[0] < 40e-6                  # ~25 us


def test_scheduling_below_1pct_at_256():
    for cfg in CONFIGS:
        t = _gemm_phases(cfg, 256)
        assert t.scheduling_fraction < 0.01
        # and it is NOT negligible at 32x32 (the paper's point)
        t32 = _gemm_phases(cfg, 32)
        assert t32.scheduling_fraction > 0.05


def test_transfer_stabilizes_near_20pct():
    """Transfer ≈ slightly more than 20 % at the large sizes (16T — the
    config the paper's high-range claim refers to)."""
    fracs = [_gemm_phases(EGPU_16T, s).transfer_fraction
             for s in (128, 192, 256)]
    for f in fracs:
        assert 0.15 < f < 0.35
    assert abs(fracs[-1] - fracs[-2]) < 0.05              # stabilized


def test_transfer_time_grows_with_size():
    t_small = _gemm_phases(EGPU_16T, 32).transfer
    t_big = _gemm_phases(EGPU_16T, 256).transfer
    assert t_big > 10 * t_small


# ---------------------------------------------------------------------------
# Fig 4: TinyBio speed-up & energy
# ---------------------------------------------------------------------------
PAPER_BANDS = {   # stage: (4T low anchor, 16T high anchor)
    "fir": (3.6, 15.1),
    "delineate": (3.1, 13.1),
    "fft": (3.3, 14.0),
    "app": (3.4, 14.3),
}
TOL = 0.20        # model-vs-silicon tolerance on each endpoint


def _tinybio_report():
    wl = TINYBIO_WORKLOAD
    stages = {
        "fir": fir_counts(n=wl["n"], taps=wl["taps"], itemsize=2),
        "delineate": del_counts(n=wl["n"]),
        "fft": fft_counts(n=wl["win"]).scaled(wl["n_windows"]),
        "svm": svm_counts(q=wl["n_windows"], m=wl["n_sv"],
                          d=wl["n_features"]),
    }
    out = {}
    for cfg in CONFIGS:
        tot_h = tot_e = eh = ee = 0.0
        per = {}
        for i, (name, c) in enumerate(stages.items()):
            if i > 0:   # resident pipeline: only stage 0 pays the D$ fill
                c = dataclasses.replace(c, host_bytes=0.0)
            te = egpu_time(cfg, c, optimal_ndrange(wl["n"], cfg))
            th = host_time(c)
            per[name] = (th.total_s / te.total_s,
                         host_energy_j(th) / egpu_energy_j(cfg, te))
            tot_h += th.total_s
            tot_e += te.total_s
            eh += host_energy_j(th)
            ee += egpu_energy_j(cfg, te)
        per["app"] = (tot_h / tot_e, eh / ee)
        out[cfg.name] = per
    return out


def test_tinybio_speedups_in_paper_bands():
    rep = _tinybio_report()
    for stage, (lo, hi) in PAPER_BANDS.items():
        s4 = rep["e-gpu-4t"][stage][0]
        s16 = rep["e-gpu-16t"][stage][0]
        assert lo * (1 - TOL) <= s4 <= lo * (1 + TOL), (stage, s4, lo)
        assert hi * (1 - TOL) <= s16 <= hi * (1 + TOL), (stage, s16, hi)


def test_tinybio_energy_reduction_band():
    rep = _tinybio_report()
    e4 = rep["e-gpu-4t"]["app"][1]
    e16 = rep["e-gpu-16t"]["app"][1]
    assert 1.7 * (1 - TOL) <= e4 <= 3.1 * (1 + TOL)
    assert 1.7 * (1 - TOL) <= e16 <= 3.1 * (1 + TOL)
    assert e16 > e4          # more parallelism → better energy (Fig 4 trend)


def test_tinybio_monotone_in_threads():
    rep = _tinybio_report()
    for stage in ("fir", "delineate", "fft", "svm", "app"):
        s = [rep[c.name][stage][0] for c in CONFIGS]
        assert s[0] < s[1] < s[2], (stage, s)


def test_divergent_stage_scales_worst():
    """§VIII-C: delineation (control-dominated) gains least from threads."""
    rep = _tinybio_report()
    gain = {st: rep["e-gpu-16t"][st][0] / rep["e-gpu-4t"][st][0]
            for st in ("fir", "delineate", "fft")}
    assert gain["delineate"] <= gain["fir"]
    assert gain["delineate"] <= gain["fft"]


# ---------------------------------------------------------------------------
# ISSUE 8: the paper's numbers ARE the DVFS anchor
# ---------------------------------------------------------------------------
def test_dvfs_anchor_reproduces_paper_numbers_bit_identically():
    """Every paper claim above is characterized at 300 MHz / 0.8 V; the
    DVFS model must reproduce those calibrated numbers EXACTLY (not
    approximately) when a config is rebased onto the anchor point."""
    from repro.core import OP_ANCHOR
    for cfg in CONFIGS + (HOST,):
        assert (cfg.freq_hz, cfg.voltage_v) == (300e6, 0.8)
        at = cfg.at(OP_ANCHOR)
        assert characterize(at) == characterize(cfg)
        assert egpu_active_power_mw(at) == egpu_active_power_mw(cfg)


def test_off_anchor_power_moves_monotonically():
    """Off the anchor the envelope moves the physical way: lower (f, V)
    strictly under the paper's 28 mW, higher strictly above it."""
    from repro.core import OPERATING_POINTS
    p_nom = egpu_active_power_mw(EGPU_16T)
    p_low = egpu_active_power_mw(EGPU_16T.at(OPERATING_POINTS["low"]))
    p_turbo = egpu_active_power_mw(EGPU_16T.at(OPERATING_POINTS["turbo"]))
    assert p_low < p_nom <= 28.0 < p_turbo
    # and leakage follows voltage, preserving the paper band at anchor
    leak = characterize(EGPU_16T).total_leak_uw
    assert characterize(
        EGPU_16T.at(OPERATING_POINTS["low"])).total_leak_uw < leak
    assert 130.13 * 0.85 <= leak <= 305.32 * 1.15
