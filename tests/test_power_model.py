"""DVFS operating points through the device + power model (ISSUE 8).

Pins the tentpole's device-layer contracts: the paper's 300 MHz / 0.8 V
point is the *calibration anchor* (scales are exactly 1.0 there, so every
historical number is bit-identical), dynamic power scales with f*V^2,
leakage with voltage, both monotonically; op points key the memoization
layers; and ``fuse_breakdowns`` normalizes mixed-op-point chains per
stage.
"""

import dataclasses
import os

import pytest

from repro.core import (EGPU_4T, EGPU_8T, EGPU_16T, OP_ANCHOR,
                        OPERATING_POINTS, OperatingPoint, env_op_point)
from repro.core.machine import PhaseBreakdown, fuse_breakdowns
from repro.core.power import (characterize, dynamic_scale, egpu_active_power_mw,
                              egpu_energy_j, egpu_idle_power_mw, leakage_scale)

LOW = OPERATING_POINTS["low"]
TURBO = OPERATING_POINTS["turbo"]


# ---------------------------------------------------------------------------
# OperatingPoint / EGPUConfig.at / env plumbing
# ---------------------------------------------------------------------------
def test_operating_point_table_and_anchor():
    assert OP_ANCHOR.freq_hz == 300e6 and OP_ANCHOR.voltage_v == 0.8
    assert OPERATING_POINTS["nominal"] is OP_ANCHOR
    assert LOW.freq_hz < OP_ANCHOR.freq_hz < TURBO.freq_hz
    assert LOW.voltage_v < OP_ANCHOR.voltage_v < TURBO.voltage_v


@pytest.mark.parametrize("freq,volt", [(0.0, 0.8), (-1.0, 0.8),
                                       (300e6, 0.0), (300e6, -0.5)])
def test_operating_point_rejects_nonpositive(freq, volt):
    with pytest.raises(ValueError):
        OperatingPoint("bad", freq, volt).validate()


def test_config_at_rebases_and_validates():
    c = EGPU_16T.at(TURBO)
    assert (c.freq_hz, c.voltage_v) == (TURBO.freq_hz, TURBO.voltage_v)
    assert c.total_threads == EGPU_16T.total_threads  # only DVFS moved
    assert c.operating_point is TURBO
    assert EGPU_16T.operating_point is OP_ANCHOR
    assert dataclasses.replace(EGPU_16T, voltage_v=0.71) \
        .operating_point.name == "custom"
    with pytest.raises(ValueError):
        dataclasses.replace(EGPU_16T, voltage_v=-1.0).validate()


def test_env_op_point_parsing(monkeypatch):
    monkeypatch.delenv("REPRO_OP_POINT", raising=False)
    assert env_op_point() is None
    monkeypatch.setenv("REPRO_OP_POINT", "low")
    assert env_op_point() == LOW
    monkeypatch.setenv("REPRO_OP_POINT", "200e6:0.7")
    p = env_op_point()
    assert (p.freq_hz, p.voltage_v) == (200e6, 0.7)
    monkeypatch.setenv("REPRO_OP_POINT", "not-a-point")
    with pytest.raises(ValueError):
        env_op_point()


# ---------------------------------------------------------------------------
# scales: exact anchor identity, monotonicity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("config", [EGPU_4T, EGPU_8T, EGPU_16T])
def test_scales_are_exactly_one_at_anchor(config):
    assert dynamic_scale(config) == 1.0
    assert leakage_scale(config) == 1.0
    assert dynamic_scale(config.at(OP_ANCHOR)) == 1.0


@pytest.mark.parametrize("config", [EGPU_4T, EGPU_8T, EGPU_16T])
def test_anchor_bit_identity(config):
    """Rebasing onto the anchor is a no-op bit for bit: characterize,
    active power, idle power and energy all reproduce the calibrated
    numbers exactly (not approximately)."""
    at = config.at(OP_ANCHOR)
    assert characterize(at) == characterize(config)
    assert egpu_active_power_mw(at) == egpu_active_power_mw(config)
    assert egpu_idle_power_mw(at) == egpu_idle_power_mw(config)
    pb = PhaseBreakdown(startup=1000, scheduling=500, transfer=2000,
                        compute=30000, freq_hz=config.freq_hz)
    assert egpu_energy_j(at, pb) == egpu_energy_j(config, pb)


def test_power_monotone_in_frequency_and_voltage():
    for base in (EGPU_8T, EGPU_16T):
        p_low = egpu_active_power_mw(base.at(LOW))
        p_nom = egpu_active_power_mw(base)
        p_turbo = egpu_active_power_mw(base.at(TURBO))
        assert p_low < p_nom < p_turbo
        # frequency alone (V fixed): dynamic power is linear in f
        faster = dataclasses.replace(base, freq_hz=base.freq_hz * 2)
        assert egpu_active_power_mw(faster) > p_nom
        # voltage alone (f fixed): both dynamic AND leakage rise
        hotter = dataclasses.replace(base, voltage_v=base.voltage_v * 1.1)
        assert egpu_active_power_mw(hotter) > p_nom
        assert characterize(hotter).total_leak_uw \
            > characterize(base).total_leak_uw
        assert egpu_idle_power_mw(base.at(LOW)) \
            < egpu_idle_power_mw(base) < egpu_idle_power_mw(base.at(TURBO))


def test_low_point_is_more_efficient_per_request():
    """The DVFS trade the serving bench exploits: low is slower but
    cheaper per unit of work; turbo faster but costlier."""
    pb = PhaseBreakdown(startup=1000, scheduling=500, transfer=2000,
                        compute=30000, freq_hz=EGPU_16T.freq_hz)

    def energy_at(point):
        c = EGPU_16T.at(point)
        return egpu_energy_j(c, dataclasses.replace(pb, freq_hz=c.freq_hz))

    assert energy_at(LOW) < energy_at(OP_ANCHOR) < energy_at(TURBO)


# ---------------------------------------------------------------------------
# op points key the memo layers
# ---------------------------------------------------------------------------
def test_graph_cache_keys_include_op_point():
    import jax.numpy as jnp
    import numpy as np

    from repro.core import APU, Kernel, Stage
    from repro.serve import GraphCache

    k = Kernel("scale", executor=lambda x: (x * 2.0,))
    stages = [Stage(k, n_inputs=1)]
    x = jnp.asarray(np.ones((4, 4), np.float32))
    cache = GraphCache(capacity=8)
    APU(EGPU_16T, graph_cache=cache).offload(stages, (x,))
    APU(EGPU_16T, graph_cache=cache).offload(stages, (x,))
    assert (cache.hits, cache.misses) == (1, 1)       # same config: shared
    APU(EGPU_16T.at(LOW), graph_cache=cache).offload(stages, (x,))
    assert (cache.hits, cache.misses) == (1, 2)       # op point: new entry


# ---------------------------------------------------------------------------
# fuse_breakdowns across op points (satellite b)
# ---------------------------------------------------------------------------
def test_fuse_chain_mixed_op_points_normalizes_per_stage():
    """Regression: chain-mode fusion used to reject mixed clocks outright;
    now each stage's cycles are normalized by ITS OWN op-point frequency
    onto the fastest clock, in both chain and DAG mode."""
    a = PhaseBreakdown(startup=300, scheduling=150, transfer=900,
                       compute=3000, freq_hz=EGPU_16T.at(TURBO).freq_hz)
    b = PhaseBreakdown(startup=300, scheduling=150, transfer=900,
                       compute=3000, freq_hz=EGPU_16T.at(LOW).freq_hz)
    chain = fuse_breakdowns([a, b])
    dag = fuse_breakdowns([a, b], deps=[(), (0,)])
    assert chain.freq_hz == TURBO.freq_hz
    assert chain == dag                                # same serial shape
    # wall-clock truth is preserved: each stage contributes its own
    # seconds, overheads paid once at the max normalized cost
    expect_s = (a.transfer + a.compute) / a.freq_hz \
        + (b.transfer + b.compute) / b.freq_hz \
        + max((a.startup + a.scheduling) / a.freq_hz,
              (b.startup + b.scheduling) / b.freq_hz)
    assert chain.total_s == pytest.approx(expect_s, rel=1e-12)
    # uniform chains stay bit-identical (scale factor is exactly 1.0)
    uniform = fuse_breakdowns([a, dataclasses.replace(a)])
    assert uniform.freq_hz == a.freq_hz
    assert uniform.transfer == a.transfer * 2


def test_characterize_lru_does_not_alias_op_points():
    seen = {characterize(EGPU_16T).total_leak_uw,
            characterize(EGPU_16T.at(LOW)).total_leak_uw,
            characterize(EGPU_16T.at(TURBO)).total_leak_uw}
    assert len(seen) == 3


def test_env_op_point_matches_direct_rebase(monkeypatch):
    monkeypatch.setenv("REPRO_OP_POINT", "turbo")
    assert os.environ["REPRO_OP_POINT"] == "turbo"
    p = env_op_point()
    assert egpu_active_power_mw(EGPU_16T.at(p)) \
        == egpu_active_power_mw(EGPU_16T.at(TURBO))
