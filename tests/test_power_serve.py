"""Power-budget-aware serving (ISSUE 8).

Pins the serve-layer tentpole: budget validation, requests-per-joule
routing under per-lane / fleet caps, loud power sheds through the
AdmissionError machinery, honest idle-leakage energy accounting in
ServeReport, the power telemetry series — and the enforcement invariant,
swept over adversarial budgets/op-point mixes/faults (hypothesis where
available, a seeded sweep everywhere): **no accepted request ever
executes on a lane whose booked window-average power exceeds its
budget** (``n_budget_violations`` stays 0).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EGPU_8T, EGPU_16T, OPERATING_POINTS, Kernel, Stage
from repro.kernels.gemm.ref import counts as gemm_counts
from repro.kernels.gemm.ref import gemm_ref
from repro.serve import (AdmissionError, DispatchError, FaultPlan, LanePrice,
                         PowerBudget, PowerBudgetError, Server, env_seed)

LOW = OPERATING_POINTS["low"]
TURBO = OPERATING_POINTS["turbo"]


class VClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _stages(n=2, d=8, seed=0):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((d, d)) * 0.2, jnp.float32)
    k = Kernel("mlp",
               executor=lambda x, w: jnp.maximum(gemm_ref(x, w), 0.0),
               counts=lambda **kw: gemm_counts(m=d, n=d, k=d))
    return [Stage(k, consts=(w,), n_inputs=1) for _ in range(n)]


def _xs(n, d=8, seed=1):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((4, d)), jnp.float32)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# PowerBudget / PowerBudgetError semantics
# ---------------------------------------------------------------------------
def test_budget_validation():
    with pytest.raises(ValueError, match="at least one"):
        PowerBudget()
    with pytest.raises(ValueError, match="positive"):
        PowerBudget(lane_mw=0.0)
    with pytest.raises(ValueError, match="positive"):
        PowerBudget(lane_mw=28.0, fleet_mw=-1.0)
    b = PowerBudget(lane_mw=28.0)
    assert b.lane_w == pytest.approx(0.028) and b.fleet_w is None
    assert b.lane_ok(0.028) and not b.lane_ok(0.0281)
    assert b.fleet_ok(1e9)                       # uncapped dimension
    f = PowerBudget(fleet_mw=56.0)
    assert f.lane_ok(1e9) and not f.fleet_ok(0.057)


def test_power_budget_error_is_a_dispatch_error():
    # the server's loud-shed machinery keys on DispatchError — the power
    # shed path must ride it, not bypass it
    assert issubclass(PowerBudgetError, DispatchError)


# ---------------------------------------------------------------------------
# routing under the caps
# ---------------------------------------------------------------------------
def test_capped_fleet_avoids_the_hot_lane():
    """A turbo lane whose draw can never fit the 28 mW cap gets throttled
    out of the rotation; traffic lands on the efficient lanes with zero
    booked violations and a bounded peak."""
    budget = PowerBudget(lane_mw=28.0, fleet_mw=35.0)
    srv = Server(_stages(), workers=(EGPU_16T.at(TURBO), EGPU_16T,
                                     EGPU_16T.at(LOW)),
                 bucket_sizes=(4,), max_batch=2, clock=VClock(),
                 power_budget=budget)
    rids = [srv.submit(x) for x in _xs(12)]
    srv.flush()
    rep = srv.report()
    assert rep.n_requests == 12 and rep.n_power_shed == 0
    assert rep.queues[0].batches == 0            # turbo never launched
    assert rep.n_power_throttled > 0
    assert rep.n_budget_violations == 0
    assert rep.peak_fleet_power_w <= 35.0e-3 + 1e-12
    assert rep.power_budget_lane_mw == 28.0
    assert rep.power_budget_fleet_mw == 35.0
    for rid in rids:
        (out,) = srv.result(rid)
        assert np.asarray(out).shape == (4, 8)


def test_impossible_budget_sheds_loudly():
    """A cap no lane can meet sheds every batch through the AdmissionError
    machinery — requests are never silently dropped OR silently served
    over budget."""
    srv = Server(_stages(), workers=(EGPU_16T, EGPU_8T), bucket_sizes=(4,),
                 max_batch=2, clock=VClock(),
                 power_budget=PowerBudget(lane_mw=1e-6))
    rids = [srv.submit(x) for x in _xs(4)]
    srv.flush()
    rep = srv.report()
    assert rep.n_requests == 0
    assert rep.n_power_shed == 4 and rep.n_shed == 4
    assert rep.n_budget_violations == 0          # nothing launched at all
    for rid in rids:
        with pytest.raises(AdmissionError, match="power budget shed"):
            srv.result(rid)


def test_uncapped_server_reports_power_defaults():
    srv = Server(_stages(), workers=(EGPU_16T,), bucket_sizes=(4,),
                 max_batch=2, clock=VClock())
    for x in _xs(4):
        srv.submit(x)
    srv.flush()
    rep = srv.report()
    assert rep.power_budget_lane_mw is None
    assert rep.power_budget_fleet_mw is None
    assert rep.n_power_shed == rep.n_power_throttled == 0
    assert rep.n_budget_violations == 0
    assert rep.peak_fleet_power_w == 0.0         # nothing samples uncapped
    # the honest energy ledger still reports, budget or not
    assert rep.fleet_energy_j > 0.0
    assert rep.requests_per_s_per_watt > 0.0


# ---------------------------------------------------------------------------
# idle-leakage energy accounting (satellite a)
# ---------------------------------------------------------------------------
def test_idle_leakage_folds_into_fleet_energy():
    """fleet_energy = active + idle, idle = sum over lanes of the
    clock-gated floor times each lane's non-serving share of the modeled
    makespan; avg power * makespan reproduces fleet energy exactly."""
    clk = VClock()
    # explicit NON-anchor op points: lanes rebased via ``.at()`` keep
    # their chosen point even under a REPRO_OP_POINT environment override
    # (only anchor-point presets follow the env), so the heterogeneous
    # fast/slow mix — and its idle time — survives any CI leg
    srv = Server(_stages(),
                 workers=(EGPU_16T.at(TURBO), EGPU_16T.at(LOW)),
                 bucket_sizes=(4,), max_batch=2, clock=clk)
    for x in _xs(8):
        srv.submit(x)
    srv.flush()
    rep = srv.report()
    span = srv._t_last_modeled - srv._t0
    assert span > 0
    active = sum(q.energy_j for q in rep.queues)
    idle = sum(max(0.0, span - q.modeled_s) * q.idle_power_w
               for q in rep.queues)
    assert idle > 0.0                            # someone idled sometime
    assert rep.fleet_idle_energy_j == pytest.approx(idle, rel=1e-12)
    assert rep.fleet_energy_j == pytest.approx(active + idle, rel=1e-12)
    assert rep.avg_fleet_power_w * span \
        == pytest.approx(rep.fleet_energy_j, rel=1e-12)
    assert rep.requests_per_s_per_watt \
        == pytest.approx(rep.n_requests / rep.fleet_energy_j, rel=1e-12)
    # idle floors differ per op point and are surfaced per lane
    floors = {q.idle_power_w for q in rep.queues}
    assert len(floors) == 2 and all(f > 0.0 for f in floors)


def test_power_metrics_published():
    srv = Server(_stages(), workers=(EGPU_16T,), bucket_sizes=(4,),
                 max_batch=2, clock=VClock(),
                 power_budget=PowerBudget(lane_mw=28.0))
    for x in _xs(4):
        srv.submit(x)
    srv.flush()
    names = set(srv.publish_metrics().snapshot())
    for expected in ("repro_fleet_avg_power_watts",
                     "repro_fleet_peak_power_watts",
                     "repro_fleet_energy_joules",
                     "repro_fleet_idle_energy_joules",
                     "repro_serve_requests_per_second_per_watt",
                     "repro_serve_goodput_per_second_per_watt",
                     "repro_serve_power_shed_total",
                     "repro_serve_power_throttled_total",
                     "repro_serve_budget_violations_total",
                     "repro_lane_idle_power_watts",
                     "repro_lane_budget_violations_total"):
        assert expected in names, expected


def test_outputs_bit_identical_across_op_points():
    """DVFS moves time and power, never math: the same traffic served on
    rebased silicon produces bit-identical outputs."""
    outs = {}
    for tag, point in (("nominal", None), ("low", LOW), ("turbo", TURBO)):
        workers = (EGPU_16T if point is None else EGPU_16T.at(point),)
        srv = Server(_stages(), workers=workers, bucket_sizes=(4,),
                     max_batch=2, clock=VClock())
        rids = [srv.submit(x) for x in _xs(6)]
        srv.flush()
        outs[tag] = [np.asarray(srv.result(r)[0]) for r in rids]
    for tag in ("low", "turbo"):
        for a, b in zip(outs["nominal"], outs[tag]):
            assert np.array_equal(a, b)


# ---------------------------------------------------------------------------
# the enforcement invariant, swept
# ---------------------------------------------------------------------------
def _budget_scenario(seed, lane_mw, fleet_mw, n_requests, p_spike, spike_s):
    """Random op-point fleet + adversarial budget + latency spikes.

    Returns the report after asserting the invariant: zero booked budget
    violations, every accepted request accounted for (result or loud
    shed), and — when a fleet cap is set — a peak draw within it.
    """
    rng = np.random.default_rng(seed)
    points = list(OPERATING_POINTS.values())
    workers = tuple(
        (EGPU_16T if rng.integers(2) else EGPU_8T).at(
            points[rng.integers(len(points))])
        for _ in range(int(rng.integers(2, 5))))
    budget = PowerBudget(lane_mw=lane_mw, fleet_mw=fleet_mw)
    plan = (FaultPlan(seed=env_seed(seed), p_latency_spike=p_spike,
                      latency_spike_s=spike_s)
            if p_spike > 0.0 else None)
    srv = Server(_stages(), workers=workers, bucket_sizes=(4,),
                 max_batch=2, clock=VClock(), fault_plan=plan,
                 power_budget=budget)
    rids = [srv.submit(x) for x in _xs(n_requests, seed=seed)]
    srv.flush()
    rep = srv.report()
    # THE invariant: the launch-time audit never caught an over-budget
    # booking — dispatch-time pricing upper-bounds the booked window
    assert rep.n_budget_violations == 0, rep.n_budget_violations
    if fleet_mw is not None:
        assert rep.peak_fleet_power_w <= fleet_mw * 1e-3 + 1e-12
    # conservation: accepted = served + loudly shed
    n_served = n_shed = 0
    for rid in rids:
        try:
            srv.result(rid)
            n_served += 1
        except AdmissionError:
            n_shed += 1
    assert n_served == rep.n_requests
    assert n_served + n_shed == n_requests
    return rep


@pytest.mark.parametrize("seed,lane_mw,fleet_mw,p_spike", [
    (env_seed(10), 28.0, None, 0.0),     # paper envelope, lane-only
    (env_seed(11), 28.0, 35.0, 0.0),     # both caps
    (env_seed(12), 6.0, 12.0, 0.0),      # tight: only low lanes fit
    (env_seed(13), 28.0, 35.0, 0.8),     # spikes lengthen booked windows
    (env_seed(14), None, 30.0, 0.3),     # fleet-only cap
    (env_seed(15), 0.5, None, 0.0),      # near-impossible: mass sheds
])
def test_no_over_budget_execution_seeded_sweep(seed, lane_mw, fleet_mw,
                                               p_spike):
    _budget_scenario(seed, lane_mw, fleet_mw, n_requests=10,
                     p_spike=p_spike, spike_s=0.2)


def test_no_over_budget_execution_property():
    """Satellite (ISSUE 8): hypothesis sweep — same invariant as the
    seeded sweep, adversarial budgets and op-point mixes."""
    pytest.importorskip("hypothesis")    # not baked into every image
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           lane_mw=st.one_of(st.none(), st.floats(0.5, 60.0)),
           fleet_mw=st.floats(1.0, 80.0),
           p_spike=st.floats(0.0, 1.0))
    def prop(seed, lane_mw, fleet_mw, p_spike):
        _budget_scenario(seed, lane_mw, fleet_mw, n_requests=8,
                         p_spike=p_spike, spike_s=0.3)

    prop()


def test_lane_price_shape():
    """LanePrice is the routing currency — its fields must reflect the
    lane's actual modeled timeline."""
    from repro.serve import QueueWorker
    w = QueueWorker(EGPU_16T, name="lane0", clock=lambda: 0.0)
    p = w.price(None, 0.0, t_now=0.0)
    assert isinstance(p, LanePrice)
    assert p.lane == "lane0" and p.window_s == 0.0 and p.avg_power_w == 0.0
    assert p.requests_per_joule == float("inf")  # free work prices infinite
