"""Tiny-OpenCL host API v2 (ISSUE 4): Program / KernelRegistry objects and
explicit buffer-transfer commands.

Pins the new contracts:

* every built-in kernel family builds through one registry on multiple
  ``EGPUConfig`` presets, numerically identical to a direct builder call,
  with ``(family, config, variant)`` memoization;
* clSetKernelArg-style ``arg_info`` / ``set_args`` / ``enqueue_kernel``;
* ``enqueue_write_buffer`` / ``read_buffer`` / ``copy_buffer`` return real
  transfer-only-costed events that compose with markers/barriers,
  ``wait_events`` and DAG capture (eager and graph modes), and the fused
  critical path overlaps transfer nodes with compute on independent
  branches;
* enforced ``Buffer`` flags, ``GraphBuffer`` flag inheritance, and the
  ``create_buffer`` copy/use_host_ptr fast paths.
"""

import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (APU, EGPU_8T, EGPU_16T, Buffer, CommandQueue,
                        Context, Device, Kernel, NDRange, Program, Stage,
                        fuse_breakdowns, kernel_family, transfer_time)
from repro.core.program import BUILTIN_FAMILIES, KernelRegistry
from repro.kernels.gemm.ref import counts as gemm_counts
from repro.kernels.gemm.ref import gemm_ref

NDR = NDRange((8, 8), (4, 4))
CONFIGS = (EGPU_8T, EGPU_16T)


def _ctx(config=EGPU_16T):
    return Context(Device(config))


def _mm_kernel(name="mm"):
    return Kernel(name=name, executor=gemm_ref,
                  counts=lambda **kw: gemm_counts(m=8, n=8, k=8))


def _x(seed=0, shape=(8, 8)):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape),
                       jnp.float32)


def _family_inputs(name):
    """Small sample invocation arrays per built-in family."""
    rng = np.random.default_rng(7)
    f32 = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    if name == "gemm":
        return (f32(16, 32), f32(32, 8))
    if name == "fir":
        return (f32(256), f32(16))
    if name == "delineate":
        return (f32(256),)
    if name == "stockham_fft":
        return (f32(128),)
    if name == "svm":
        return (f32(8, 12), f32(16, 12), f32(16), jnp.float32(0.1))
    if name == "mamba_scan":
        return (f32(1, 32, 4), jnp.abs(f32(1, 32, 4)) * 0.1,
                -jnp.abs(f32(4, 2)), f32(1, 32, 2), f32(1, 32, 2), f32(4))
    if name == "decode_attention":
        return (f32(1, 2, 8), f32(1, 2, 16, 8), f32(1, 2, 16, 8))
    raise AssertionError(f"no sample inputs for family {name!r}")


# ---------------------------------------------------------------------------
# Registry smoke: every family x >= 2 configs, legacy-identical, memoized
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("config", CONFIGS, ids=lambda c: c.name)
@pytest.mark.parametrize("family", sorted(BUILTIN_FAMILIES))
def test_registry_builds_every_family(family, config):
    program = Program.build(config)
    kern = program.create_kernel(family)
    assert kern.family == family and kern.config is config
    assert kern.counts is not None
    # memoized: a second program build hands out the SAME kernel object
    assert Program.build(config).create_kernel(family) is kern
    # numerically identical to a direct builder call (a fresh,
    # distinct kernel object that bypasses the registry memo)
    ops = importlib.import_module(BUILTIN_FAMILIES[family])
    legacy = ops.build_kernel(config)
    ins = _family_inputs(family)
    got, want = kern.executor(*ins), legacy.executor(*ins)
    got = got if isinstance(got, tuple) else (got,)
    want = want if isinstance(want, tuple) else (want,)
    for g, w in zip(got, want):
        assert np.array_equal(np.asarray(g), np.asarray(w))


def test_program_exposes_all_seven_builtin_families():
    program = Program.build(EGPU_16T)
    assert set(BUILTIN_FAMILIES) <= set(program.kernel_names)
    kernels = program.create_kernels()
    assert set(BUILTIN_FAMILIES) <= set(kernels)
    assert len(BUILTIN_FAMILIES) == 7


def test_variants_and_configs_are_distinct_memo_entries():
    p16, p8 = Program.build(EGPU_16T), Program.build(EGPU_8T)
    base = p16.create_kernel("gemm")
    assert base is p16.create_kernel("gemm", use_pallas=True)  # canonical
    assert base is not p16.create_kernel("gemm", use_pallas=False)
    assert base is not p8.create_kernel("gemm")
    with pytest.raises(KeyError):
        p16.create_kernel("no_such_family")


def test_private_registry_and_app_registration():
    reg = KernelRegistry()

    @kernel_family("app.scale", registry=reg)
    def build_scale(config, *, k=2.0):
        return Kernel("scale", executor=lambda x: x * k)

    prog = Program.build(EGPU_16T, registry=reg)
    assert prog.kernel_names == ("app.scale",)
    kern = prog.create_kernel("app.scale", k=3.0)
    np.testing.assert_allclose(np.asarray(kern.executor(jnp.ones(4))), 3.0)
    # double registration is loud (same name, different builder)
    with pytest.raises(ValueError):
        kernel_family("app.scale", registry=reg)(lambda config: None)


def test_tinybio_stage_kernels_are_stable_across_builds():
    from repro.apps.tinybio import tinybio_stages
    s1, _ = tinybio_stages(EGPU_16T)
    s2, _ = tinybio_stages(EGPU_16T)
    for a, b in zip(s1, s2):
        assert a.kernel is b.kernel, a.kernel.name


# ---------------------------------------------------------------------------
# clSetKernelArg-style introspection
# ---------------------------------------------------------------------------
def test_arg_info_classifies_buffers_and_params():
    kern = Program.build(EGPU_16T).create_kernel("svm")
    info = kern.arg_info
    assert [a.name for a in info if a.kind == "buffer"] == [
        "x", "sv", "alpha", "b"]
    assert [a.name for a in info if a.kind == "param"] == ["gamma"]
    # gamma is a defaulted positional: it may be fed as a buffer too
    assert kern.n_buffer_args == (4, 5)


def test_set_args_enqueue_kernel_matches_enqueue_nd_range():
    ctx = _ctx()
    q = CommandQueue(ctx)
    kern = _mm_kernel()
    a, b = _x(1), _x(2)
    kern.set_args(a, b)
    e1 = q.enqueue_kernel(kern, NDR)
    e2 = q.enqueue_nd_range(kern, NDR,
                            (ctx.create_buffer(a), ctx.create_buffer(b)))
    q.finish()
    assert np.array_equal(np.asarray(e1.outputs[0].data),
                          np.asarray(e2.outputs[0].data))
    assert e1.modeled is not None
    assert e1.modeled.total_cycles == e2.modeled.total_cycles


def test_set_arg_by_index_and_arity_errors():
    kern = Kernel("f", executor=lambda a, b, gamma=0.5: a * gamma)
    x = _x(3)
    kern.set_arg(0, x).set_arg(1, x).set_arg(2, 0.25)
    bufs, params = kern.staged_args()
    assert len(bufs) == 2 and params == {"gamma": 0.25}
    with pytest.raises(ValueError):
        kern.set_args(x)                     # too few buffers
    with pytest.raises(RuntimeError):
        Kernel("g", executor=lambda a, b: a).staged_args()


# ---------------------------------------------------------------------------
# Explicit transfer commands — eager mode
# ---------------------------------------------------------------------------
def test_write_read_copy_are_transfer_only_events():
    ctx = _ctx()
    q = CommandQueue(ctx)
    x = _x(4)
    dst = ctx.create_buffer(jnp.zeros_like(x))
    wev = q.enqueue_write_buffer(dst, x)
    expect = transfer_time(EGPU_16T, x.size * 4)
    assert wev.modeled.transfer == expect.transfer > 0
    assert wev.modeled.compute == wev.modeled.startup == 0.0
    assert wev.energy_j is not None and wev.energy_j > 0
    assert np.array_equal(np.asarray(dst.data), np.asarray(x))

    rev = q.enqueue_read_buffer(dst)
    assert rev.modeled.transfer == expect.transfer
    (out,) = rev.wait()
    assert np.array_equal(np.asarray(out.data), np.asarray(x))

    cpy = ctx.create_buffer(jnp.zeros_like(x))
    cev = q.enqueue_copy_buffer(dst, cpy)
    assert cev.modeled.transfer == expect.transfer
    q.finish()
    assert np.array_equal(np.asarray(cpy.data), np.asarray(x))
    # transfers are queue events: modeled totals include them
    assert q.total_modeled_s() >= 3 * expect.total_s


def test_transfers_chain_and_compose_with_markers_and_barriers():
    ctx = _ctx()
    q = CommandQueue(ctx, out_of_order=True)
    x = _x(5)
    buf = ctx.create_buffer(jnp.zeros_like(x))
    wev = q.enqueue_write_buffer(buf, x)
    # dataflow: a kernel consuming the written buffer depends on the write
    kev = q.enqueue_nd_range(_mm_kernel(), NDR, (buf, buf))
    assert wev in kev.deps
    # wait_events: a read ordered after the kernel via the explicit list
    rev = q.enqueue_read_buffer(kev.outputs[0], wait_events=[kev])
    assert kev in rev.deps
    m = q.enqueue_marker()               # aggregates everything so far
    assert set(m.deps) >= {wev, kev, rev}
    bar = q.enqueue_barrier()
    w2 = q.enqueue_write_buffer(ctx.create_buffer(jnp.zeros_like(x)), x)
    assert bar in w2.deps                # out-of-order: barrier edge only
    q.finish()
    assert all(e.done for e in (wev, kev, rev, w2))
    np.testing.assert_allclose(np.asarray(rev.outputs[0].data),
                               np.asarray(x) @ np.asarray(x), rtol=1e-5)


def test_in_order_queue_chains_transfers_implicitly():
    ctx = _ctx()
    q = CommandQueue(ctx)
    x = _x(6)
    b1 = ctx.create_buffer(jnp.zeros_like(x))
    e1 = q.enqueue_write_buffer(b1, x)
    e2 = q.enqueue_read_buffer(b1)
    assert e1 in e2.deps                 # implicit in-order edge
    e3 = q.enqueue_write_buffer(b1, x * 2, blocking=True)   # CL_TRUE
    assert e3.done
    np.testing.assert_allclose(np.asarray(b1.data), np.asarray(x) * 2)


def test_transfer_shape_dtype_validation():
    ctx = _ctx()
    q = CommandQueue(ctx)
    dst = ctx.create_buffer(jnp.zeros((8, 8), jnp.float32))
    with pytest.raises(ValueError, match="does not match"):
        q.enqueue_write_buffer(dst, jnp.zeros((4, 4), jnp.float32))
    with pytest.raises(ValueError, match="does not match"):
        q.enqueue_copy_buffer(dst, ctx.create_buffer(
            jnp.zeros((8, 8), jnp.int32)))


# ---------------------------------------------------------------------------
# Buffer flag enforcement
# ---------------------------------------------------------------------------
def test_flags_are_enforced():
    ctx = _ctx()
    q = CommandQueue(ctx)
    x = _x(7)
    ro = ctx.create_buffer(x, flags="r")
    wo = ctx.create_buffer(x, flags="w")
    rw = ctx.create_buffer(x)
    with pytest.raises(ValueError, match="read-only"):
        q.enqueue_write_buffer(ro, x)
    with pytest.raises(ValueError, match="read-only"):
        q.enqueue_copy_buffer(rw, ro)
    with pytest.raises(ValueError, match="write-only"):
        q.enqueue_read_buffer(wo)
    with pytest.raises(ValueError, match="write-only"):
        q.enqueue_nd_range(_mm_kernel(), NDR, (wo, rw))
    with pytest.raises(ValueError, match="write-only"):
        q.enqueue_copy_buffer(wo, rw)
    # the same contracts hold under capture
    with q.capture():
        with pytest.raises(ValueError, match="read-only"):
            q.enqueue_write_buffer(ro, x)
        with pytest.raises(ValueError, match="write-only"):
            q.enqueue_nd_range(_mm_kernel(), NDR, (wo, rw))
    with pytest.raises(ValueError):
        Buffer(x, flags="rx")


def test_graphbuffer_inherits_source_flags():
    ctx = _ctx()
    q = CommandQueue(ctx)
    x = _x(8)
    ro = ctx.create_buffer(x, flags="r")
    with q.capture() as g:
        rev = q.enqueue_read_buffer(ro)      # read from a read-only buffer
        kev = q.enqueue_nd_range(_mm_kernel(), NDR, (rev.outputs[0],
                                                     rev.outputs[0]))
    assert rev.outputs[0].flags == "r"       # inherited, not hardcoded "rw"
    assert kev.outputs[0].flags == "rw"      # kernel outputs stay fresh
    assert [n.kind for n in g.nodes] == ["read", "kernel"]


# ---------------------------------------------------------------------------
# Transfer commands under capture: graph nodes + critical-path overlap
# ---------------------------------------------------------------------------
def test_capture_records_transfer_nodes_and_matches_eager():
    ctx = _ctx()
    x = _x(9)
    q = CommandQueue(ctx)
    with q.capture() as g:
        buf = Buffer(jnp.zeros_like(x))
        q.enqueue_write_buffer(buf, x)
        kev = q.enqueue_nd_range(_mm_kernel(), NDR, (buf, buf),
                                 _resident=True)
        q.enqueue_read_buffer(kev.outputs[0])
    assert [n.kind for n in g.nodes] == ["write", "kernel", "read"]
    assert g.node_deps() == ((), (0,), (1,))
    assert g.nodes[0].nbytes == x.size * 4
    (out,) = g.launch()
    np.testing.assert_allclose(np.asarray(out.data),
                               np.asarray(x) @ np.asarray(x), rtol=1e-5)
    # fused model prices the explicit traffic: write + read bytes over the
    # bus, with the kernel marked resident
    fused, _ = g.fused_modeled()
    assert fused.transfer == pytest.approx(
        2 * transfer_time(EGPU_16T, x.size * 4).transfer)


def test_capture_write_orders_after_readers_of_old_value():
    """Write-after-read: overwriting a buffer must depend on every captured
    node that consumed the OLD value, not just its producer — otherwise the
    critical path models the overwrite as concurrent with its readers."""
    ctx = _ctx()
    x = _x(17)
    q = CommandQueue(ctx, out_of_order=True)
    with q.capture() as g:
        buf = Buffer(jnp.zeros_like(x))
        q.enqueue_write_buffer(buf, x)               # 0: producer
        q.enqueue_read_buffer(buf)                   # 1: reader of old value
        q.enqueue_nd_range(_mm_kernel(), NDR, (buf, buf),
                           _resident=True)           # 2: reader of old value
        q.enqueue_write_buffer(buf, x * 2)           # 3: overwrite
    deps = g.node_deps()
    assert set(deps[3]) >= {1, 2}                    # WAR edges, not just {0}
    # flags still enforced on the write path's source buffer
    wo_src = ctx.create_buffer(x, flags="w")
    with pytest.raises(ValueError, match="write-only"):
        CommandQueue(ctx).enqueue_write_buffer(
            ctx.create_buffer(jnp.zeros_like(x)), wo_src)


def test_capture_copy_buffer_rebinds_destination():
    """A captured copy node: consumers of the destination observe the
    copied value, and the node models one bus transfer."""
    ctx = _ctx()
    x = _x(16)
    q = CommandQueue(ctx)
    with q.capture() as g:
        src = ctx.create_buffer(x)
        dst = Buffer(jnp.zeros_like(x))
        q.enqueue_copy_buffer(src, dst)
        kev = q.enqueue_nd_range(_mm_kernel(), NDR, (dst, dst),
                                 _resident=True)
        q.enqueue_read_buffer(kev.outputs[0])
    assert [n.kind for n in g.nodes] == ["copy", "kernel", "read"]
    assert g.nodes[0].nbytes == x.size * 4
    (out,) = g.launch()
    np.testing.assert_allclose(np.asarray(out.data),
                               np.asarray(x) @ np.asarray(x), rtol=1e-5)


def test_trailing_reads_define_graph_outputs():
    ctx = _ctx()
    x = _x(10)
    q = CommandQueue(ctx)
    with q.capture() as g:
        a = ctx.create_buffer(x)
        e1 = q.enqueue_nd_range(_mm_kernel("A"), NDR, (a, a))
        e2 = q.enqueue_nd_range(_mm_kernel("B"), NDR, (e1.outputs[0], a))
        q.enqueue_read_buffer(e1.outputs[0])
        q.enqueue_read_buffer(e2.outputs[0])
    outs = g.launch()
    assert len(outs) == 2                # one per trailing read, in order
    np.testing.assert_allclose(np.asarray(outs[0].data),
                               np.asarray(x) @ np.asarray(x), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(outs[1].data),
                               np.asarray(outs[0].data) @ np.asarray(x),
                               rtol=1e-4)


def test_critical_path_overlaps_branch_transfers_with_compute():
    """Acceptance: explicit transfer nodes on independent out-of-order
    branches overlap with compute in the fused critical path — the chain
    model (same nodes, serial) is strictly slower, and the critical path
    hides the smaller branch entirely."""
    ctx = _ctx()
    q = CommandQueue(ctx, out_of_order=True)
    xa, xb = _x(11), _x(12)
    with q.capture() as g:
        ba, bb = Buffer(jnp.zeros_like(xa)), Buffer(jnp.zeros_like(xb))
        q.enqueue_write_buffer(ba, xa)
        q.enqueue_write_buffer(bb, xb)
        ka = q.enqueue_nd_range(_mm_kernel("A"), NDR, (ba, ba),
                                _resident=True)
        kb = q.enqueue_nd_range(_mm_kernel("B"), NDR, (bb, bb),
                                _resident=True)
        q.enqueue_nd_range(_mm_kernel("combine"), NDR,
                           (ka.outputs[0], kb.outputs[0]),
                           wait_events=[ka, kb], _resident=True)
    kinds = [n.kind for n in g.nodes]
    assert kinds == ["write", "write", "kernel", "kernel", "kernel"]
    # two independent branches: write->kernel chains meeting at the combine
    assert g.node_deps() == ((), (), (0,), (1,), (2, 3))
    fused, _ = g.fused_modeled()
    chain = fuse_breakdowns(g.modeled_breakdowns())
    assert fused.total_s < chain.total_s
    # the critical path carries ONE branch (write + kernel) + combine; the
    # sibling branch's transfer happens during it
    per_write = g.nodes[0].modeled
    per_kernel = g.nodes[2].modeled
    assert fused.transfer == pytest.approx(per_write.transfer)
    assert fused.compute == pytest.approx(2 * per_kernel.compute)
    assert chain.transfer == pytest.approx(2 * per_write.transfer)
    # and the whole thing still computes the right numbers
    (out,) = g.launch()
    np.testing.assert_allclose(
        np.asarray(out.data),
        (np.asarray(xa) @ np.asarray(xa)) @ (np.asarray(xb) @ np.asarray(xb)),
        rtol=1e-4)


def test_apu_capture_pipeline_explicit_transfers():
    """The serving capture shape: write -> resident kernels -> read, with
    launch_prefix results bit-identical to the classic capture."""
    apu = APU(EGPU_16T)
    kern = apu.program.create_kernel("gemm")
    stages = [Stage(kern, counts_params={"m": 8, "n": 8, "k": 8}),
              Stage(kern, counts_params={"m": 8, "n": 8, "k": 8},
                    n_inputs=1, consts=(_x(14),))]
    x = _x(13)
    classic = apu.capture_pipeline(stages, (x, x))
    explicit = apu.capture_pipeline(stages, (x, x), explicit_transfers=True)
    assert [n.kind for n in explicit.nodes] == [
        "write", "write", "kernel", "kernel", "read"]
    # kernels are resident: no heuristic per-kernel transfer phase
    for node in explicit.nodes:
        if node.kind == "kernel":
            assert node.modeled.transfer == 0.0
    y = _x(15)
    got = explicit.launch_prefix([y, y])
    want = classic.launch_prefix([y, y])
    assert np.array_equal(np.asarray(got[0].data), np.asarray(want[0].data))
    # APU flag wires through offload and stays report-consistent
    apu2 = APU(EGPU_16T, explicit_transfers=True)
    outs, report = apu2.offload(stages, (x, x))
    assert np.array_equal(
        np.asarray(outs[0].data),
        np.asarray(apu.offload(stages, (x, x))[0][0].data))
    assert len(report.stages) == len(stages)
    assert report.egpu_fused is not None


# ---------------------------------------------------------------------------
# create_buffer fast paths (CL_MEM_USE_HOST_PTR)
# ---------------------------------------------------------------------------
def test_create_buffer_copy_and_use_host_ptr():
    ctx = _ctx()
    x = jnp.arange(16, dtype=jnp.float32)
    assert ctx.create_buffer(x).data is x            # jax.Array: adopted
    assert ctx.create_buffer(x, copy=False).data is x
    assert ctx.create_buffer(x, use_host_ptr=True).data is x
    assert ctx.create_buffer(x, copy=True).data is not x
    host = np.arange(4, dtype=np.float32)
    assert isinstance(ctx.create_buffer(host).data, jax.Array)
    with pytest.raises(TypeError):
        ctx.create_buffer(host, copy=False)          # cannot adopt numpy
    with pytest.raises(TypeError):
        ctx.create_buffer(host, use_host_ptr=True)
    with pytest.raises(ValueError):
        ctx.create_buffer(x, copy=True, use_host_ptr=True)
