"""Async TinyCL queue + CommandGraph semantics (ISSUE 1).

Covers the new execution model: non-blocking enqueue with in-order event
chaining, ``finish()`` draining, jit-cache correctness across static-arg
signatures, zero-cost events in the queue totals, graph capture/launch
equivalence with eager dispatch (including the full TinyBio pipeline), and
buffer-donation safety.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps.tinybio import run_tinybio, tinybio_stages
from repro.core import (APU, EGPU_16T, CommandQueue, Context, Device, Event,
                        GraphBuffer, Kernel, NDRange, PhaseBreakdown, Stage,
                        WorkCounts, fuse_breakdowns)
from repro.kernels.gemm.ref import gemm_ref

NDR = NDRange((8, 8), (4, 4))


def _ctx():
    return Context(Device(EGPU_16T))


def _mm_kernel():
    return Kernel(name="mm", executor=gemm_ref)


# ---------------------------------------------------------------------------
# Asynchronous queue semantics
# ---------------------------------------------------------------------------
def test_async_enqueue_chains_in_order():
    ctx = _ctx()
    q = CommandQueue(ctx, profile=False)
    a = ctx.create_buffer(jnp.arange(64, dtype=jnp.float32).reshape(8, 8))
    eye = ctx.create_buffer(jnp.eye(8, dtype=jnp.float32))
    e1 = q.enqueue_nd_range(_mm_kernel(), NDR, (a, eye))
    assert not e1.done                   # non-blocking: not yet synchronized
    e2 = q.enqueue_nd_range(_mm_kernel(), NDR, e1.outputs + (eye,))
    (out,) = e2.wait()
    assert e2.done
    np.testing.assert_allclose(np.asarray(out.data), np.asarray(a.data))


def test_finish_drains_all_events():
    ctx = _ctx()
    q = CommandQueue(ctx, profile=False)
    a = ctx.create_buffer(jnp.ones((8, 8), jnp.float32))
    evs = [q.enqueue_nd_range(_mm_kernel(), NDR, (a, a)) for _ in range(4)]
    assert not any(e.done for e in evs)
    q.finish()
    assert all(e.done for e in evs)


def test_finish_watermark_only_drains_new_events():
    # profiled queue: full history retained, watermark advances monotonically
    ctx = _ctx()
    q = CommandQueue(ctx)
    a = ctx.create_buffer(jnp.ones((8, 8), jnp.float32))
    q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    q.finish()
    assert q._drained == 1
    e2 = q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    q.finish()                           # drains only the new event
    assert q._drained == 2 and e2.done
    q.finish()                           # idempotent on a drained queue
    assert q._drained == 2


def test_unprofiled_finish_releases_events():
    """An unprofiled queue auto-releases on finish (ISSUE-2 satellite): a
    long-lived service queue stays O(in-flight) memory."""
    ctx = _ctx()
    q = CommandQueue(ctx, profile=False)
    a = ctx.create_buffer(jnp.ones((8, 8), jnp.float32))
    evs = [q.enqueue_nd_range(_mm_kernel(), NDR, (a, a)) for _ in range(3)]
    q.finish()
    assert q.events == () and q.released_count == 3
    assert all(e.done and e.released and e.outputs == () for e in evs)
    # the queue keeps working after a release sweep
    e = q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    q.finish()
    assert e.done and q.released_count == 4


def test_blocking_queue_syncs_each_launch():
    ctx = _ctx()
    q = CommandQueue(ctx, profile=False, blocking=True)
    a = ctx.create_buffer(jnp.ones((8, 8), jnp.float32))
    ev = q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    assert ev.done


# ---------------------------------------------------------------------------
# jit cache keyed on static-arg signature (satellite fix)
# ---------------------------------------------------------------------------
def test_jit_cache_not_frozen_on_first_call_statics():
    """The same kernel may be enqueued with a param as a static python value
    in one call and as a traced array in the next; each (name, statics)
    signature must get its own jit wrapper."""
    ctx = _ctx()
    q = CommandQueue(ctx, profile=False)
    kern = Kernel(name="scale", executor=lambda x, scale=1.0: x * scale)
    a = ctx.create_buffer(jnp.ones(4, jnp.float32))

    (o1,) = q.enqueue_nd_range(kern, NDR, (a,),
                               params={"scale": 2.0}).wait()
    # same kernel, scale now a jax array — the old cache reused the wrapper
    # with static_argnames=("scale",) and crashed on the unhashable array
    (o2,) = q.enqueue_nd_range(kern, NDR, (a,),
                               params={"scale": jnp.float32(3.0)}).wait()
    np.testing.assert_allclose(np.asarray(o1.data), 2.0 * np.ones(4))
    np.testing.assert_allclose(np.asarray(o2.data), 3.0 * np.ones(4))


def test_jit_cache_shape_static_added_after_first_call():
    ctx = _ctx()
    q = CommandQueue(ctx, profile=False)
    kern = Kernel(name="reshape",
                  executor=lambda x, rows=1: x.reshape(rows, -1))
    a = ctx.create_buffer(jnp.arange(8, dtype=jnp.float32))
    (o1,) = q.enqueue_nd_range(kern, NDR, (a,)).wait()
    # `rows` must be static (used in a shape); the old cache jitted with the
    # first call's empty static set, so this traced `rows` and crashed
    (o2,) = q.enqueue_nd_range(kern, NDR, (a,), params={"rows": 2}).wait()
    assert o1.data.shape == (1, 8)
    assert o2.data.shape == (2, 4)


# ---------------------------------------------------------------------------
# Queue totals must not drop zero-valued costs (satellite fix)
# ---------------------------------------------------------------------------
def test_totals_count_zero_cost_events():
    ctx = _ctx()
    q = CommandQueue(ctx, profile=False)
    pb = PhaseBreakdown(startup=0.0, scheduling=0.0, transfer=0.0,
                        compute=300.0, freq_hz=300e6)
    zero_pb = PhaseBreakdown(0.0, 0.0, 0.0, 0.0, freq_hz=300e6)
    k = _mm_kernel()
    q._events.extend([
        Event(k, (), pb, 1e-6, 0.0),
        Event(k, (), zero_pb, 0.0, 0.0),     # legit fully-resident stage
        Event(k, (), None, None, 0.0),       # unprofiled launch
    ])
    assert q.total_modeled_s() == pytest.approx(pb.total_s)
    assert q.total_energy_j() == pytest.approx(1e-6)
    # the zero-cost event is *counted* (is-not-None filter), not dropped
    counted = [e for e in q.events if e.modeled is not None]
    assert len(counted) == 2


# ---------------------------------------------------------------------------
# CommandGraph capture / launch
# ---------------------------------------------------------------------------
def test_capture_records_without_executing():
    ctx = _ctx()
    q = CommandQueue(ctx, profile=False)
    a = ctx.create_buffer(jnp.ones((8, 8), jnp.float32))
    with q.capture() as graph:
        ev = q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
        assert isinstance(ev.outputs[0], GraphBuffer)
        assert ev.outputs[0].shape == (8, 8)
        with pytest.raises(RuntimeError):
            ev.outputs[0].read()         # no data exists during capture
    assert len(graph.nodes) == 1
    assert q.events == ()                # nothing ran, nothing recorded


def test_graph_matches_eager_chain():
    ctx = _ctx()
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)

    q = CommandQueue(ctx, profile=False)
    ab = ctx.create_buffer(a)
    bb = ctx.create_buffer(b)
    e1 = q.enqueue_nd_range(_mm_kernel(), NDR, (ab, bb))
    e2 = q.enqueue_nd_range(_mm_kernel(), NDR, e1.outputs + (bb,))
    (eager,) = e2.wait()

    q2 = CommandQueue(ctx, profile=False)
    with q2.capture() as graph:
        c1 = q2.enqueue_nd_range(_mm_kernel(), NDR,
                                 (ctx.create_buffer(a), ctx.create_buffer(b)))
        q2.enqueue_nd_range(_mm_kernel(), NDR,
                            c1.outputs + (ctx.create_buffer(b),))
    (fused,) = graph.launch()
    np.testing.assert_allclose(np.asarray(fused.data),
                               np.asarray(eager.data), atol=1e-5)


def test_graph_relaunch_with_new_inputs():
    ctx = _ctx()
    q = CommandQueue(ctx, profile=False)
    a = ctx.create_buffer(jnp.ones((8, 8), jnp.float32))
    b = ctx.create_buffer(jnp.eye(8, dtype=jnp.float32))
    with q.capture() as graph:
        q.enqueue_nd_range(_mm_kernel(), NDR, (a, b))
    assert graph.n_external == 2
    x = jnp.full((8, 8), 2.0, jnp.float32)
    (out,) = graph.launch(x, x)
    np.testing.assert_allclose(np.asarray(out.data),
                               np.asarray(x @ x), atol=1e-5)
    with pytest.raises(ValueError):
        graph.launch(x)                  # arity mismatch
    with pytest.raises(ValueError):
        # shape mismatch must be loud: a silent retrace would attach
        # capture-time modeled costs to a different-sized computation
        graph.launch(jnp.ones((16, 16), jnp.float32), x)
    with pytest.raises(ValueError):
        graph.launch(x.astype(jnp.int32), x)     # dtype mismatch
    # a buffer enqueued twice is ONE external slot (dedup by identity)
    q2 = CommandQueue(ctx, profile=False)
    with q2.capture() as g2:
        q2.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    assert g2.n_external == 1
    (out2,) = g2.launch(x)
    np.testing.assert_allclose(np.asarray(out2.data),
                               np.asarray(x @ x), atol=1e-5)


def test_graph_launch_registers_queue_events():
    ctx = _ctx()
    q = CommandQueue(ctx)                # profiled
    a = ctx.create_buffer(jnp.ones(64, jnp.float32))
    counts = lambda **kw: WorkCounts(ops=64, dcache_bytes=256, host_bytes=256,
                                     working_set=256)
    kern = Kernel(name="twice", executor=lambda x: x * 2, counts=counts)
    with q.capture() as graph:
        ev = q.enqueue_nd_range(kern, NDR, (a,))
        q.enqueue_nd_range(kern, NDR, ev.outputs, _resident=True)
    graph.launch()
    q.finish()
    assert len(q.events) == 2
    assert q.total_modeled_s() > 0.0
    # capture costed the resident stage: no host<->D$ transfer modeled
    assert q.events[1].modeled.transfer == 0.0
    assert q.events[0].modeled.transfer > 0.0


def test_graph_donation_does_not_corrupt_visible_buffers():
    ctx = _ctx()
    q = CommandQueue(ctx, profile=False)
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    with q.capture() as graph:
        ab, bb = ctx.create_buffer(a), ctx.create_buffer(b)
        ev = q.enqueue_nd_range(_mm_kernel(), NDR, (ab, bb))
        q.enqueue_nd_range(_mm_kernel(), NDR, ev.outputs + (bb,))
    expect = np.asarray((a @ b) @ b)

    scratch = jnp.array(a)               # donated: consumed by the launch
    (out,) = graph.launch(scratch, b, donate=(0,))
    np.testing.assert_allclose(np.asarray(out.data), expect, atol=1e-4)
    # the NON-donated input must stay intact and reusable
    np.testing.assert_array_equal(np.asarray(b), np.asarray(
        jnp.asarray(b)))
    (out2,) = graph.launch(jnp.array(a), b)
    np.testing.assert_allclose(np.asarray(out2.data), expect, atol=1e-4)
    # donating the graph's own captured arrays would poison later
    # zero-argument launches — must be rejected up front
    with pytest.raises(ValueError):
        graph.launch(donate=(0,))
    (out3,) = graph.launch()             # captured externals still valid
    np.testing.assert_allclose(np.asarray(out3.data), expect, atol=1e-4)


def test_capture_aborted_by_exception_is_not_launchable():
    ctx = _ctx()
    q = CommandQueue(ctx, profile=False)
    a = ctx.create_buffer(jnp.ones((8, 8), jnp.float32))
    with pytest.raises(KeyError):
        with q.capture() as graph:
            q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
            raise KeyError("boom mid-capture")
    assert q._capture is None            # queue usable again
    with pytest.raises(RuntimeError):
        graph.launch()                   # truncated chain must not run
    # a fresh capture on the same queue works
    with q.capture() as g2:
        q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    assert len(g2.launch()) == 1


def test_offload_graph_without_counts_still_returns_outputs():
    """Kernels with no machine model must not break the default graph
    mode — outputs come back; only the cost report is empty."""
    apu = APU(EGPU_16T)
    x = jnp.ones((8, 8), jnp.float32)
    stage = Stage(Kernel(name="mm_nocounts", executor=gemm_ref))
    outs, report = apu.offload([stage], (x, x))
    np.testing.assert_allclose(np.asarray(outs[0].data),
                               np.asarray(x @ x), atol=1e-5)
    assert report.egpu_fused is None and report.fused_speedup is None
    assert report.overall_speedup is None
    assert report.overall_energy_reduction is None
    outs_e, _ = apu.offload([stage], (x, x), mode="eager")
    np.testing.assert_allclose(np.asarray(outs[0].data),
                               np.asarray(outs_e[0].data))


def test_fuse_breakdowns_pays_dispatch_once():
    pb = PhaseBreakdown(startup=100.0, scheduling=200.0, transfer=50.0,
                        compute=1000.0, freq_hz=300e6)
    fused = fuse_breakdowns([pb, pb, pb])
    assert fused.startup == 100.0 and fused.scheduling == 200.0
    assert fused.transfer == 150.0 and fused.compute == 3000.0
    assert fused.total_cycles < 3 * pb.total_cycles
    with pytest.raises(ValueError):
        fuse_breakdowns([])
    # mixed clocks normalize instead of raising (ISSUE 8 DVFS op points):
    # a slower stage's wall time is preserved on the fastest clock
    slow = dataclasses.replace(pb, freq_hz=150e6)
    mixed = fuse_breakdowns([pb, slow])
    assert mixed.freq_hz == 300e6
    assert mixed.total_s == pytest.approx(pb.total_s + slow.total_s
                                          - (pb.startup + pb.scheduling)
                                          / pb.freq_hz)


# ---------------------------------------------------------------------------
# Full TinyBio pipeline: graph == eager, accounting preserved
# ---------------------------------------------------------------------------
def test_tinybio_graph_equals_eager():
    d_graph, r_graph = run_tinybio(EGPU_16T, mode="graph")
    d_eager, r_eager = run_tinybio(EGPU_16T, mode="eager")
    np.testing.assert_allclose(np.asarray(d_graph), np.asarray(d_eager),
                               atol=1e-5)
    assert len(r_graph.stages) == len(r_eager.stages) == 4
    for sg, se in zip(r_graph.stages, r_eager.stages):
        # identical per-stage machine-model numbers (costed from the
        # captured schedule, not wall clock)
        assert sg.egpu.total_s == se.egpu.total_s
        assert sg.host.total_s == se.host.total_s
        assert sg.egpu_energy_j == se.egpu_energy_j
        assert sg.host_energy_j == se.host_energy_j
    # the fused chain amortizes startup+scheduling → strictly faster than
    # the per-kernel sum
    assert r_graph.egpu_fused is not None
    assert r_graph.fused_speedup > r_graph.overall_speedup


def test_tinybio_graph_relaunch_consistent():
    apu = APU(EGPU_16T)
    stages, inputs = tinybio_stages(EGPU_16T)
    graph = apu.capture_pipeline(stages, inputs)
    (o1,) = graph.launch(queue_events=False)
    (o2,) = graph.launch(queue_events=False)
    np.testing.assert_allclose(np.asarray(o1.data), np.asarray(o2.data))
