"""repro.serve — graph cache, dynamic batching, multi-queue dispatch
(ISSUE 2).

Pins the subsystem's contracts: cache hit/miss/eviction and config
isolation, cached-launch numerical identity with fresh capture, batcher
padding at bucket boundaries, the warm-server zero-re-capture guarantee
with bit-identical batched results, event-lifecycle memory bounds, and
dispatcher backpressure.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (APU, EGPU_4T, EGPU_8T, EGPU_16T, CommandQueue,
                        Context, Device, Kernel, NDRange, Stage, WorkCounts)
from repro.kernels.gemm.ref import counts as gemm_counts
from repro.kernels.gemm.ref import gemm_ref
from repro.serve import (BucketBatcher, GraphCache, MultiQueueDispatcher,
                         QueueWorker, Server, batched_stages)

NDR = NDRange((8, 8), (4, 4))


def _mm_stages(d=8, seed=0, n=1):
    """n chained (x @ W -> relu) stages with a fixed weight."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((d, d)) * 0.2, jnp.float32)

    def mlp(x, w):
        return jnp.maximum(gemm_ref(x, w), 0.0)

    kern = Kernel("mlp", executor=mlp,
                  counts=lambda **kw: gemm_counts(m=d, n=d, k=d))
    return [Stage(kern, consts=(w,), n_inputs=1) for _ in range(n)]


def _x(shape=(8, 8), seed=1):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape),
                       jnp.float32)


# ---------------------------------------------------------------------------
# GraphCache
# ---------------------------------------------------------------------------
def test_cache_hit_miss_counters():
    cache = GraphCache(capacity=4)
    apu = APU(EGPU_16T, graph_cache=cache)
    stages = _mm_stages()
    x = _x()
    o1, _ = apu.offload(stages, (x,))
    assert (cache.hits, cache.misses) == (0, 1)
    o2, _ = apu.offload(stages, (x,))
    assert (cache.hits, cache.misses) == (1, 1)
    np.testing.assert_array_equal(np.asarray(o1[0].data),
                                  np.asarray(o2[0].data))
    # a different input SHAPE is a different entry
    apu.offload(stages, (_x((4, 8)),))
    assert (cache.hits, cache.misses) == (1, 2)


def test_cache_lru_eviction():
    cache = GraphCache(capacity=2)
    apu = APU(EGPU_16T, graph_cache=cache)
    stages = _mm_stages()
    xa, xb, xc = _x((2, 8)), _x((4, 8)), _x((6, 8))
    apu.offload(stages, (xa,))
    apu.offload(stages, (xb,))
    apu.offload(stages, (xa,))           # promote A to MRU
    apu.offload(stages, (xc,))           # evicts B (LRU)
    assert cache.evictions == 1 and len(cache) == 2
    apu.offload(stages, (xb,))           # B must re-capture (evicts A)
    assert cache.misses == 4 and cache.evictions == 2
    apu.offload(stages, (xc,))           # C still resident
    assert cache.hits == 2


def test_cache_distinct_configs_do_not_collide():
    cache = GraphCache(capacity=8)
    stages = _mm_stages()
    x = _x()
    outs = {}
    for cfg in (EGPU_4T, EGPU_8T, EGPU_16T):
        apu = APU(cfg, graph_cache=cache)
        (o,), rep = apu.offload(stages, (x,))
        outs[cfg.name] = np.asarray(o.data)
        # each config modeled with its own machine numbers
        assert rep.stages[0].egpu is not None
    assert cache.misses == 3 and cache.hits == 0
    # same pipeline again on each config: all hits now
    for cfg in (EGPU_4T, EGPU_8T, EGPU_16T):
        APU(cfg, graph_cache=cache).offload(stages, (x,))
    assert cache.hits == 3
    for name, o in outs.items():         # functional results config-invariant
        np.testing.assert_array_equal(o, outs[EGPU_16T.name])


def test_cache_distinct_consts_do_not_collide():
    """Same kernel names, different baked weights => different entries (a
    false hit would serve the wrong model)."""
    cache = GraphCache(capacity=8)
    apu = APU(EGPU_16T, graph_cache=cache)
    x = _x()
    (o1,), _ = apu.offload(_mm_stages(seed=0), (x,))
    (o2,), _ = apu.offload(_mm_stages(seed=7), (x,))
    assert cache.misses == 2
    assert not np.array_equal(np.asarray(o1.data), np.asarray(o2.data))


def test_cache_distinct_closures_do_not_collide():
    """Two lambdas born at the same source line capturing different values
    must get different entries — a false hit replays the wrong capture."""
    cache = GraphCache(capacity=8)
    apu = APU(EGPU_16T, graph_cache=cache)
    x = jnp.ones((4,), jnp.float32)

    def scale_stage(k):
        return [Stage(Kernel("scale", executor=lambda a: a * k))]

    (o2,), _ = apu.offload(scale_stage(2.0), (x,))
    (o3,), _ = apu.offload(scale_stage(3.0), (x,))
    assert cache.misses == 2
    np.testing.assert_array_equal(np.asarray(o2.data), 2.0 * np.ones(4))
    np.testing.assert_array_equal(np.asarray(o3.data), 3.0 * np.ones(4))
    # identical capture value => genuine hit
    apu.offload(scale_stage(2.0), (x,))
    assert cache.hits == 1


def test_cache_distinct_inline_literals_do_not_collide():
    """Executors differing only in an inline constant share co_code — the
    signature must still tell them apart (co_consts hashed)."""
    cache = GraphCache(capacity=8)
    apu = APU(EGPU_16T, graph_cache=cache)
    x = jnp.ones((4,), jnp.float32)
    (o2,), _ = apu.offload([Stage(Kernel("s", executor=lambda a: a * 2.0))],
                           (x,))
    (o3,), _ = apu.offload([Stage(Kernel("s", executor=lambda a: a * 3.0))],
                           (x,))
    assert cache.misses == 2
    np.testing.assert_array_equal(np.asarray(o2.data), 2.0 * np.ones(4))
    np.testing.assert_array_equal(np.asarray(o3.data), 3.0 * np.ones(4))


def test_cache_large_arrays_in_containers_do_not_collide():
    """A closure capturing a LIST of large arrays must sign element-wise —
    repr truncates big arrays to '...', which would collide."""
    cache = GraphCache(capacity=8)
    apu = APU(EGPU_16T, graph_cache=cache)
    x = jnp.ones((4,), jnp.float32)
    w1 = np.zeros(10_000, np.float32)
    w2 = w1.copy()
    w2[5_000] = 1.0                      # differs only mid-array

    def stage_for(ws):
        return [Stage(Kernel("pick", executor=lambda a: a * ws[0][5_000]))]

    (o1,), _ = apu.offload(stage_for([jnp.asarray(w1)]), (x,))
    (o2,), _ = apu.offload(stage_for([jnp.asarray(w2)]), (x,))
    assert cache.misses == 2             # no false hit
    np.testing.assert_array_equal(np.asarray(o1.data), np.zeros(4))
    np.testing.assert_array_equal(np.asarray(o2.data), np.ones(4))


def test_batch_dim_padding_uses_fill_value():
    b = BucketBatcher((4,), max_batch=3, fill=1.0)
    b.submit(jnp.full((4,), 2.0, jnp.float32))
    (mb,) = b.drain()
    # dead capacity rows use the configured fill, not zeros (kernels like
    # 1/x rely on it to stay finite)
    np.testing.assert_array_equal(np.asarray(mb.inputs[0][1:]),
                                  np.ones((2, 4), np.float32))


def test_cached_offload_reuses_pipeline_report():
    cache = GraphCache(capacity=4)
    apu = APU(EGPU_16T, graph_cache=cache)
    stages = _mm_stages()
    _, r1 = apu.offload(stages, (_x(),))
    _, r2 = apu.offload(stages, (_x(seed=5),))
    assert r2 is r1                      # launch-invariant, memoized


def test_cache_signature_memo_reused_for_same_stage_objects():
    cache = GraphCache(capacity=8)
    apu = APU(EGPU_16T, graph_cache=cache)
    stages = _mm_stages()
    x = _x()
    apu.offload(stages, (x,))
    apu.offload(stages, (x,))
    assert len(cache._sig_memo) == 1     # same Stage list: hashed once
    assert (cache.hits, cache.misses) == (1, 1)


def test_cached_launch_identical_to_fresh_capture():
    cache = GraphCache(capacity=4)
    cached_apu = APU(EGPU_16T, graph_cache=cache)
    fresh_apu = APU(EGPU_16T)            # no cache: re-captures every call
    stages = _mm_stages(n=3)
    for seed in (1, 2, 3):
        x = _x(seed=seed)
        (oc,), rep_c = cached_apu.offload(stages, (x,))
        (of,), rep_f = fresh_apu.offload(stages, (x,))
        np.testing.assert_array_equal(np.asarray(oc.data),
                                      np.asarray(of.data))
        # machine-model accounting identical through the cached path
        assert rep_c.overall_speedup == rep_f.overall_speedup
        assert rep_c.egpu_fused.total_s == rep_f.egpu_fused.total_s
    assert cache.misses == 1 and cache.hits == 2


# ---------------------------------------------------------------------------
# BucketBatcher
# ---------------------------------------------------------------------------
def test_bucket_selection_and_boundaries():
    b = BucketBatcher((8, 16), max_batch=2)
    assert b.bucket_size_for(1) == 8
    assert b.bucket_size_for(8) == 8     # exactly on the boundary: no bump
    assert b.bucket_size_for(9) == 16
    assert b.bucket_size_for(16) == 16
    with pytest.raises(ValueError):
        b.bucket_size_for(17)


def test_batcher_pads_and_crops_at_bucket_boundary():
    b = BucketBatcher((8,), max_batch=2)
    r1 = b.submit(jnp.arange(5, dtype=jnp.float32))     # padded 5 -> 8
    r2 = b.submit(jnp.arange(8, dtype=jnp.float32))     # exact fit: no pad
    (mb,) = b.pop_full()
    assert mb.inputs[0].shape == (2, 8)
    np.testing.assert_array_equal(
        np.asarray(mb.inputs[0][0]), [0, 1, 2, 3, 4, 0, 0, 0])
    np.testing.assert_array_equal(
        np.asarray(mb.inputs[0][1]), np.arange(8, dtype=np.float32))
    # crop returns each request's true extent
    outs = mb.crop([mb.inputs[0] * 2])
    assert outs[0][0].shape == (5,) and outs[1][0].shape == (8,)
    np.testing.assert_array_equal(np.asarray(outs[0][0]), [0, 2, 4, 6, 8])


def test_batcher_partial_batch_padded_to_capacity():
    b = BucketBatcher((4,), max_batch=3)
    b.submit(jnp.ones(4, jnp.float32))
    assert b.pop_full() == [] and b.n_pending == 1
    (mb,) = b.drain()
    assert mb.inputs[0].shape == (3, 4)  # batch dim padded to capacity
    assert mb.n_requests == 1 and b.n_pending == 0
    np.testing.assert_array_equal(np.asarray(mb.inputs[0][1]), np.zeros(4))


def test_batcher_pad_axis_1_crops_columns():
    """pad_axis=1: padding and cropping act on columns, not rows."""
    b = BucketBatcher((8,), max_batch=1, pad_axis=1)
    r = b.submit(jnp.ones((3, 5), jnp.float32))
    assert r.lengths == (5,)
    (mb,) = b.drain()
    assert mb.inputs[0].shape == (1, 3, 8)      # (batch, rows, padded cols)
    np.testing.assert_array_equal(np.asarray(mb.inputs[0][0, :, 5:]),
                                  np.zeros((3, 3)))
    ((out,),) = [mb.crop([mb.inputs[0] * 2])[0]]
    assert out.shape == (3, 5)                  # columns cropped back
    np.testing.assert_array_equal(np.asarray(out), 2 * np.ones((3, 5)))


def test_crop_outputs_false_returns_padded_rows():
    """Pipelines whose outputs have fixed dims equal to a bucket size must
    be able to opt out of the shape-match crop heuristic."""
    b = BucketBatcher((8,), max_batch=1, crop_outputs=False)
    r = b.submit(jnp.arange(5, dtype=jnp.float32))
    (mb,) = b.drain()
    (row,) = mb.crop([mb.inputs[0] * 2])[0]
    assert row.shape == (8,)             # padded extent kept
    assert r.lengths == (5,)             # caller slices with this


def test_batched_stages_scale_counts():
    stages = _mm_stages()
    bs = batched_stages(stages, batch=4)
    base = stages[0].kernel.counts()
    scaled = bs[0].kernel.counts()
    assert scaled.ops == 4 * base.ops
    assert scaled.host_bytes == 4 * base.host_bytes


# ---------------------------------------------------------------------------
# Warm server: zero re-captures, bit-identical results (acceptance)
# ---------------------------------------------------------------------------
def test_warm_server_zero_recaptures_and_bit_identical():
    stages = _mm_stages(n=2)
    srv = Server(stages, workers=(EGPU_16T,), bucket_sizes=(8,),
                 max_batch=2, max_in_flight=2)
    rng = np.random.default_rng(3)
    rids = []
    for _ in range(8):                   # 4 full batches, one bucket
        x = jnp.asarray(rng.standard_normal(
            (int(rng.integers(3, 9)), 8)), jnp.float32)
        rids.append((srv.submit(x), x))
    srv.flush()
    # ZERO re-captures after the first: one bucket x one worker = 1 miss
    assert srv.cache.misses == 1
    assert srv.cache.hits == 3
    # batched results bit-identical to per-request eager offload
    apu = APU(EGPU_16T)
    for rid, x in rids:
        (got,) = srv.result(rid)
        ref, _ = apu.offload(stages, (x,), mode="eager")
        assert np.array_equal(np.asarray(got), np.asarray(ref[0].data))


def test_server_warmup_precaptures_every_bucket_worker_pair():
    stages = _mm_stages()
    srv = Server(stages, workers=(EGPU_16T, EGPU_8T), bucket_sizes=(4, 8),
                 max_batch=2)
    captured = srv.warmup(jnp.zeros((1, 8), jnp.float32))
    assert captured == 4                 # 2 buckets x 2 workers
    assert srv.warmup(jnp.zeros((1, 8), jnp.float32)) == 0   # idempotent
    rng = np.random.default_rng(5)
    for _ in range(12):
        srv.submit(jnp.asarray(rng.standard_normal(
            (int(rng.integers(1, 9)), 8)), jnp.float32))
    srv.flush()
    assert srv.cache.misses == 4         # nothing re-captured after warmup
    rep = srv.report()
    assert rep.n_requests == 12
    assert rep.modeled_latency_s[50] > 0.0
    assert rep.modeled_energy_per_request_j > 0.0
    assert rep.cache["misses"] == 4
    assert sum(q.requests for q in rep.queues) == 12
    assert len(rep.summary()) > 0


def test_server_report_percentiles_ordered():
    stages = _mm_stages()
    srv = Server(stages, workers=(EGPU_16T,), bucket_sizes=(4, 32),
                 max_batch=2)
    rng = np.random.default_rng(9)
    for n in (2, 2, 30, 30, 3, 3):       # two buckets => two latency classes
        srv.submit(jnp.asarray(rng.standard_normal((n, 8)), jnp.float32))
    srv.flush()
    rep = srv.report()
    assert (rep.modeled_latency_s[50] <= rep.modeled_latency_s[90]
            <= rep.modeled_latency_s[99])
    assert rep.modeled_cost_per_request_s <= rep.modeled_latency_s[99]


# ---------------------------------------------------------------------------
# Multi-queue dispatch + backpressure
# ---------------------------------------------------------------------------
def test_dispatcher_balances_and_bounds_in_flight():
    """Homogeneous lanes split traffic evenly (modeled speeds tie, so the
    requests-served fallback alternates); the in-flight bound holds."""
    stages = _mm_stages()
    srv = Server(stages, workers=(EGPU_16T, EGPU_16T), bucket_sizes=(8,),
                 max_batch=1, max_in_flight=2)
    rng = np.random.default_rng(11)
    for _ in range(10):
        srv.submit(jnp.asarray(rng.standard_normal((8, 8)), jnp.float32))
    srv.flush()
    rep = srv.report()
    per_worker = {q.name: q for q in rep.queues}
    assert len(per_worker) == 2
    # equal-speed routing splits a 10-batch stream across both lanes
    assert all(q.batches == 5 for q in rep.queues)
    # the in-flight window is respected and backpressure engaged
    assert all(q.peak_in_flight <= 2 for q in rep.queues)
    assert all(q.backpressure_stalls > 0 for q in rep.queues)
    assert all(w.depth == 0 for w in srv.dispatcher.workers)   # drained


def test_dispatcher_heterogeneous_mix_favors_modeled_faster_lane():
    """A 16T lane models faster per request than an 8T one, so it wins
    depth ties and attracts more traffic — while the slow lane still
    bootstraps and serves."""
    stages = _mm_stages()
    srv = Server(stages, workers=(EGPU_8T, EGPU_16T), bucket_sizes=(8,),
                 max_batch=1, max_in_flight=2)
    rng = np.random.default_rng(11)
    for _ in range(10):
        srv.submit(jnp.asarray(rng.standard_normal((8, 8)), jnp.float32))
    srv.flush()
    per = {q.config: q for q in srv.report().queues}
    assert per["e-gpu-16t"].batches > per["e-gpu-8t"].batches
    assert per["e-gpu-8t"].batches >= 1
    assert all(q.peak_in_flight <= 2 for q in srv.report().queues)


def test_pick_tiebreak_uses_modeled_speed_not_requests_served():
    """Regression (ISSUE 5): after a warmup imbalance, the 16T worker has
    served MORE requests than the 8T one — under the old raw-n_requests
    tie-break it lost every depth tie from then on, permanently routing
    new traffic to the slower lane.  The tie must go to the lane with the
    lower modeled seconds-per-request."""
    stages = _mm_stages()
    slow = QueueWorker(EGPU_8T, name="slow")
    fast = QueueWorker(EGPU_16T, name="fast")
    srv = Server(stages, workers=(slow, fast), bucket_sizes=(8,),
                 max_batch=1)
    dispatcher = srv.dispatcher

    def submit_to(worker):
        x = jnp.ones((8, 8), jnp.float32)
        batch = srv.batcher._collate(
            srv.batcher.bucket_key_for((x,)),
            [srv.batcher.submit(x)])
        graph, _ = srv.cache.get_or_capture(
            worker.apu, srv._bstages, batch.inputs, key_prefix=srv._bsig)
        worker.launch(graph, batch)

    # warmup: one batch each, plus ONE extra on the fast worker
    submit_to(slow)
    submit_to(fast)
    submit_to(fast)
    for w in dispatcher.workers:
        w.drain()
    assert fast.n_requests > slow.n_requests       # the historical trap
    assert all(w.depth == 0 for w in dispatcher.workers)
    spr = {w.name: w.modeled_s_per_request() for w in dispatcher.workers}
    assert spr["fast"] < spr["slow"]
    # equal depth, model data on both: the FAST lane must win the tie
    assert dispatcher.pick() is fast


def test_pick_falls_back_to_requests_served_without_model_data():
    """Cold workers (no modeled launch yet) keep the original
    least-requests-served tie-break, and are preferred over warm lanes at
    equal depth so every lane bootstraps its model."""
    cold_a = QueueWorker(EGPU_16T, name="a")
    cold_b = QueueWorker(EGPU_8T, name="b")
    d = MultiQueueDispatcher([cold_a, cold_b])
    assert d.pick() is cold_a                      # stable order on full tie
    cold_a.n_requests = 3                          # simulate served history
    assert d.pick() is cold_b                      # fewer requests wins
    cold_a.n_requests = 0
    cold_b.n_requests = 5
    cold_b.modeled_s = 1e-3                        # b warms up
    assert d.pick() is cold_a                      # cold lane bootstraps first


def test_retire_releases_only_own_event_segment():
    """Retiring the oldest of two in-flight launches on ONE cached graph
    must not drain or release the newer launch's events — and every event
    lives on the WORKER's queue (launch-time binding), never on the cached
    graph's capture queue."""
    stages = _mm_stages(n=2)
    srv = Server(stages, workers=(EGPU_16T,), bucket_sizes=(8,),
                 max_batch=1, max_in_flight=2)
    rng = np.random.default_rng(13)
    for _ in range(2):                   # two launches, same bucket/graph
        srv.submit(jnp.asarray(rng.standard_normal((8, 8)), jnp.float32))
    (worker,) = srv.dispatcher.workers
    assert worker.depth == 2
    (graph,) = srv.cache._graphs.values()
    # host API v2: the worker's capture brackets the 2 kernel stages with
    # explicit write (inputs) and read (outputs) transfer nodes
    n_nodes = len(graph.nodes)
    assert n_nodes == 4
    assert [n.kind for n in graph.nodes] == ["write", "kernel", "kernel",
                                             "read"]
    retired = worker._retire_oldest()
    assert retired.n_events == n_nodes
    # exactly one launch's segment released; the in-flight one retained
    assert worker.queue.released_count == n_nodes
    assert len(worker.queue.events) == n_nodes
    # the cached graph's own capture queue saw none of it
    assert graph.queue.events == () and graph.queue.released_count == 0
    srv.flush()
    assert (worker.queue.released_count == 2 * n_nodes
            and worker.queue.events == ())


def test_worker_rejects_bad_config():
    with pytest.raises(ValueError):
        QueueWorker(EGPU_16T, max_in_flight=0)
    with pytest.raises(ValueError):
        MultiQueueDispatcher([])
    w1, w2 = QueueWorker(EGPU_16T, name="a"), QueueWorker(EGPU_8T, name="a")
    with pytest.raises(ValueError):
        MultiQueueDispatcher([w1, w2])


# ---------------------------------------------------------------------------
# Event lifecycle: bounded profiling window, retain, accounting
# ---------------------------------------------------------------------------
def _counts_kernel():
    return Kernel(
        "twice", executor=lambda x: x * 2,
        counts=lambda **kw: WorkCounts(ops=64, dcache_bytes=256,
                                       host_bytes=256, working_set=256))


def test_release_events_preserves_totals():
    ctx = Context(Device(EGPU_16T))
    q = CommandQueue(ctx)
    a = ctx.create_buffer(jnp.ones(64, jnp.float32))
    for _ in range(4):
        q.enqueue_nd_range(_counts_kernel(), NDR, (a,))
    q.finish()
    before_s, before_j = q.total_modeled_s(), q.total_energy_j()
    assert before_s > 0
    n = q.release_events()
    assert n == 4 and q.events == () and q.released_count == 4
    assert q.total_modeled_s() == pytest.approx(before_s)
    assert q.total_energy_j() == pytest.approx(before_j)


def test_release_events_skips_undrained():
    ctx = Context(Device(EGPU_16T))
    q = CommandQueue(ctx)
    a = ctx.create_buffer(jnp.ones(64, jnp.float32))
    q.enqueue_nd_range(_counts_kernel(), NDR, (a,))
    q.finish()
    ev = q.enqueue_nd_range(_counts_kernel(), NDR, (a,))   # in flight
    assert q.release_events() == 1       # only the drained one
    assert q.events == (ev,) and not ev.released
    q.finish()


def test_bounded_profiling_window():
    ctx = Context(Device(EGPU_16T))
    q = CommandQueue(ctx, max_events=2)
    a = ctx.create_buffer(jnp.ones(64, jnp.float32))
    evs = []
    for i in range(5):
        evs.append(q.enqueue_nd_range(_counts_kernel(), NDR, (a,)))
        q.finish()
    assert len(q.events) == 2            # window, not full history
    assert q.released_count == 3
    # totals still cover all five launches
    one = evs[0].modeled.total_s
    assert q.total_modeled_s() == pytest.approx(5 * one)


def test_event_retain_survives_queue_release():
    ctx = Context(Device(EGPU_16T))
    q = CommandQueue(ctx)
    a = ctx.create_buffer(jnp.ones(64, jnp.float32))
    kept = q.enqueue_nd_range(_counts_kernel(), NDR, (a,)).retain()
    dropped = q.enqueue_nd_range(_counts_kernel(), NDR, (a,))
    q.finish()
    q.release_events()
    assert dropped.released and dropped.outputs == ()
    assert not kept.released and len(kept.outputs) == 1    # holder's ref
    kept.release()
    assert kept.released and kept.outputs == ()
    with pytest.raises(RuntimeError):
        kept.retain()


def test_launch_prefix_replaces_leading_externals_only():
    apu = APU(EGPU_16T)
    stages = _mm_stages()
    x = _x()
    graph = apu.capture_pipeline(stages, (x,))
    assert graph.n_request_inputs == 1
    y = _x(seed=9)
    (out,) = graph.launch_prefix((y,), queue_events=False)
    w = stages[0].consts[0]
    np.testing.assert_array_equal(
        np.asarray(out.data),
        np.asarray(jnp.maximum(gemm_ref(y, w), 0.0)))
    with pytest.raises(ValueError):
        graph.launch_prefix((y, y, y))   # more inputs than externals
    with pytest.raises(ValueError):
        # donating a non-replaced position would consume the captured
        # constant buffer every later launch still needs
        graph.launch_prefix((y,), donate=(1,))
    # fused accounting is memoized and launch-invariant
    assert graph.fused_modeled() is graph.fused_modeled()


def test_server_results_store_bounded_by_metrics_window():
    """Regression (ISSUE 5): completed-but-never-fetched results must not
    accumulate forever — the store is bounded to `metrics_window` and an
    evicted read raises the flush-the-server KeyError with an explicit
    eviction hint."""
    stages = _mm_stages()
    srv = Server(stages, workers=(EGPU_16T,), bucket_sizes=(8,),
                 max_batch=1, metrics_window=4)
    rng = np.random.default_rng(21)
    rids = []
    for _ in range(10):                  # > window, nothing ever fetched
        rids.append(srv.submit(
            jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)))
    srv.flush()
    assert len(srv._results) == 4        # O(window), not O(traffic)
    rep = srv.report()
    assert rep.results_evicted == 6
    assert "6 unread results evicted" in rep.summary()
    # the newest `window` results are still readable
    for rid in rids[-4:]:
        (out,) = srv.result(rid)
        assert out.shape == (8, 8)
    # an evicted rid raises the existing KeyError, now with the hint
    with pytest.raises(KeyError, match="evicted"):
        srv.result(rids[0])
    # an id that was READ (not evicted) keeps the plain message
    with pytest.raises(KeyError) as exc:
        srv.result(rids[-1])
    assert "flush" in str(exc.value)


def test_server_results_keep_refreshes_lru():
    """keep=True is a real LRU touch: an actively-polled result must not
    age out behind completions that arrived after its last read."""
    stages = _mm_stages()
    srv = Server(stages, workers=(EGPU_16T,), bucket_sizes=(8,),
                 max_batch=1, metrics_window=3)
    rng = np.random.default_rng(23)

    def one():
        return srv.submit(
            jnp.asarray(rng.standard_normal((8, 8)), jnp.float32))

    kept = one()
    srv.flush()
    for _ in range(4):                   # > window newer completions, but
        one()                            # the kept rid is re-read each round
        srv.flush()
        (out,) = srv.result(kept, keep=True)
    assert out.shape == (8, 8)           # still readable: LRU refreshed
    (final,) = srv.result(kept)          # and still poppable at the end
    np.testing.assert_array_equal(np.asarray(out), np.asarray(final))


def test_oversize_request_unified_error_at_submit():
    """Regression (ISSUE 5): both historical oversize paths — the bare
    bucket_size_for ValueError and pad_to's extent/target mismatch — are
    replaced by ONE submit-time error naming the array index, axis,
    extent and largest configured bucket."""
    b = BucketBatcher((4, 8), max_batch=2)
    # path 1: single-array request, pad-axis extent exceeds every bucket
    with pytest.raises(ValueError, match=(
            r"array 0 has extent 9 along pad_axis 0.*largest configured "
            r"bucket 8")):
        b.submit(jnp.zeros(9, jnp.float32))
    # path 2: multi-array request — the offending array is NAMED, instead
    # of a later pad_to failure with no request context
    with pytest.raises(ValueError, match=(
            r"array 1 has extent 12 along pad_axis 0.*largest configured "
            r"bucket 8")):
        b.submit(jnp.zeros(3, jnp.float32), jnp.zeros(12, jnp.float32))
    assert b.n_pending == 0              # nothing half-staged
    # the same unified error surfaces through Server.submit
    stages = _mm_stages()
    srv = Server(stages, workers=(EGPU_16T,), bucket_sizes=(8,), max_batch=1)
    with pytest.raises(ValueError, match="oversize request: array 0"):
        srv.submit(jnp.zeros((99, 8), jnp.float32))
    # pad_to itself stays loud (and now names the axis) for direct callers
    from repro.serve import pad_to
    with pytest.raises(ValueError, match="extent 9 along axis 0"):
        pad_to(jnp.zeros(9, jnp.float32), 8)


def test_server_result_pops_by_default():
    stages = _mm_stages()
    srv = Server(stages, workers=(EGPU_16T,), bucket_sizes=(8,), max_batch=1)
    rid = srv.submit(jnp.ones((8, 8), jnp.float32))
    srv.flush()
    (out,) = srv.result(rid, keep=True)
    (again,) = srv.result(rid)           # keep=True left it readable
    np.testing.assert_array_equal(np.asarray(out), np.asarray(again))
    with pytest.raises(KeyError):
        srv.result(rid)                  # default read popped it


# ---------------------------------------------------------------------------
# Open-loop front door (ISSUE 6): satellites + SLO intake
# ---------------------------------------------------------------------------
class _Boom:
    """A buffer payload whose realization fails (simulated device fault)."""

    def block_until_ready(self):
        raise RuntimeError("simulated realization failure")


def test_retire_drains_segment_even_when_realization_raises():
    """Regression (ISSUE 6): if block_until_ready() raises inside
    _retire_oldest, the ticket is already popped — the drain/release of
    its event segment must STILL run (finally), or the lane's per-queue
    accounting is permanently skewed against every later ticket."""
    stages = _mm_stages(n=2)
    srv = Server(stages, workers=(EGPU_16T,), bucket_sizes=(8,),
                 max_batch=1, max_in_flight=2)
    rng = np.random.default_rng(13)
    for _ in range(2):
        srv.submit(jnp.asarray(rng.standard_normal((8, 8)), jnp.float32))
    (worker,) = srv.dispatcher.workers
    assert worker.depth == 2
    oldest = worker._inflight[0]
    n = oldest.n_events
    oldest.outputs[0].data = _Boom()     # poison the oldest ticket
    with pytest.raises(RuntimeError, match="simulated realization failure"):
        worker._retire_oldest()
    # the failure propagated, but the segment was drained + released
    assert worker.queue.released_count == n
    assert worker.depth == 1
    # the lane is NOT poisoned: later tickets retire with exact accounting
    srv.submit(jnp.asarray(rng.standard_normal((8, 8)), jnp.float32))
    srv.flush()
    assert worker.queue.released_count == 3 * n
    assert worker.queue.events == () and worker.depth == 0


def test_batcher_rejects_malformed_construction():
    """Satellite (ISSUE 6): unsorted / duplicate / non-positive bucket
    lists and max_batch < 1 fail loudly at construction, not obscurely at
    bucket-selection time."""
    with pytest.raises(ValueError, match="ascending"):
        BucketBatcher((256, 64, 1024))
    with pytest.raises(ValueError, match="duplicate"):
        BucketBatcher((64, 64, 256))
    with pytest.raises(ValueError, match="positive"):
        BucketBatcher((0, 64))
    with pytest.raises(ValueError, match="positive"):
        BucketBatcher((-4, 64))
    with pytest.raises(ValueError, match="at least one bucket"):
        BucketBatcher(())
    with pytest.raises(ValueError, match="max_batch"):
        BucketBatcher((64,), max_batch=0)
    # well-formed input still constructs
    assert BucketBatcher((64, 256)).bucket_sizes == (64, 256)


def test_rejected_first_submit_does_not_start_wall_clock():
    """Satellite (ISSUE 6): _t0 is stamped only once a request is actually
    ACCEPTED — a server whose first submit is rejected (oversize) must not
    charge the idle gap before the first real request to its wall clock."""
    stages = _mm_stages()
    srv = Server(stages, workers=(EGPU_16T,), bucket_sizes=(8,), max_batch=1)
    with pytest.raises(ValueError, match="oversize"):
        srv.submit(jnp.zeros((99, 8), jnp.float32))
    assert srv._t0 is None               # clock never started
    srv.submit(jnp.ones((8, 8), jnp.float32))
    assert srv._t0 is not None
    srv.flush()
    assert srv.report().n_requests == 1


def test_admission_sheds_when_queue_full_and_preempts_by_priority():
    """max_pending bounds the staged queue: an equal-priority submit sheds
    loudly; a HIGHER-priority submit preempts the lowest-priority pending
    request instead (whose result() then raises AdmissionError)."""
    from repro.serve import AdmissionError
    stages = _mm_stages()
    srv = Server(stages, workers=(EGPU_16T,), bucket_sizes=(8,),
                 max_batch=8, max_pending=2)
    r0 = srv.submit(jnp.ones((8, 8), jnp.float32), priority=0)
    r1 = srv.submit(jnp.ones((8, 8), jnp.float32), priority=1)
    # queue full, same priority as the weakest pending: shed at the door
    with pytest.raises(AdmissionError, match="max_pending"):
        srv.submit(jnp.ones((8, 8), jnp.float32), priority=0)
    assert srv.n_shed == 1
    # higher priority: preempts r0 (lowest priority pending) and is admitted
    r2 = srv.submit(2.0 * jnp.ones((8, 8), jnp.float32), priority=5)
    assert srv.batcher.n_pending == 2
    srv.flush()
    with pytest.raises(AdmissionError, match="preempted"):
        srv.result(r0)
    for rid in (r1, r2):
        (out,) = srv.result(rid)
        assert np.asarray(out).shape == (8, 8)
    rep = srv.report()
    assert rep.n_shed == 2 and rep.n_requests == 2


def test_admission_sheds_infeasible_deadline_and_deadline_flush():
    """Modeled-capacity admission: once the fleet is profiled, a deadline
    budget smaller than the predicted completion sheds at the door; a
    feasible deadline-carrying request launches its PARTIAL bucket when
    the budget is at risk (tick), instead of waiting for capacity."""
    from repro.serve import AdmissionError
    stages = _mm_stages()
    t = [0.0]
    srv = Server(stages, workers=(EGPU_16T,), bucket_sizes=(8,),
                 max_batch=4, clock=lambda: t[0])
    # profile the lane: one full batch through
    for _ in range(4):
        srv.submit(jnp.ones((8, 8), jnp.float32))
    srv.flush()
    assert srv.report().n_requests == 4
    spr = srv.dispatcher.workers[0].modeled_s_per_request()
    assert spr is not None and spr > 0
    # an absurdly tight budget is infeasible -> shed loudly
    with pytest.raises(AdmissionError, match="deadline budget"):
        srv.submit(jnp.ones((8, 8), jnp.float32), deadline=spr * 1e-6)
    assert srv.n_shed == 1
    # a feasible budget is admitted; advancing the clock to the at-risk
    # point deadline-flushes the partial (1/4-full) bucket
    rid = srv.submit(jnp.ones((8, 8), jnp.float32), deadline=1000.0 * spr)
    assert srv.batcher.n_pending == 1
    t[0] += 999.0 * spr
    srv.tick()
    assert srv.batcher.n_pending == 0
    assert srv.batcher.deadline_flushes == 1
    srv.flush()
    (out,) = srv.result(rid)
    assert np.asarray(out).shape == (8, 8)
    assert srv.report().deadline_flushes == 1


def test_deadline_validation_and_violation_accounting():
    """deadline must be a positive budget; a request whose modeled
    completion exceeds its absolute deadline counts as a violation in the
    report (completed late, not shed)."""
    stages = _mm_stages()
    t = [0.0]
    srv = Server(stages, workers=(EGPU_16T,), bucket_sizes=(8,),
                 max_batch=1, admission=False, clock=lambda: t[0])
    with pytest.raises(ValueError, match="positive budget"):
        srv.submit(jnp.ones((8, 8), jnp.float32), deadline=-1.0)
    # admission off: an infeasible deadline is ACCEPTED, completes late
    rid = srv.submit(jnp.ones((8, 8), jnp.float32), deadline=1e-12)
    srv.flush()
    (out,) = srv.result(rid)             # still completes, bit-identical
    assert np.asarray(out).shape == (8, 8)
    rep = srv.report()
    assert rep.n_deadline_violations == 1
    assert rep.n_shed == 0
