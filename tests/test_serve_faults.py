"""Open-loop front door under injected failures (ISSUE 6).

Pins the robustness contracts: FaultPlan determinism, blackout-driven
rerouting with bit-identical retried results, circuit-breaker
trip/half-open-probe/recovery, latency spikes that inflate modeled time
but never energy, loud shedding on dispatch exhaustion, and the
none-silently-lost / bit-identical property over randomized fault plans
(hypothesis where available, a seeded sweep everywhere).

The CI fault-injection leg sets ``REPRO_FAULT_SEED``; probabilistic draws
here go through :func:`repro.serve.env_seed` so every PR exercises the
machinery under a fresh seed, while the assertions lean on
seed-independent :class:`Blackout` windows and invariants (never on a
particular draw landing).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import APU, EGPU_16T, Kernel, Stage
from repro.kernels.gemm.ref import counts as gemm_counts
from repro.kernels.gemm.ref import gemm_ref
from repro.serve import (AdmissionError, Blackout, CircuitBreaker,
                         DispatchError, FaultPlan, InjectedFault, Server,
                         env_seed)

LANE0, LANE1 = "0:e-gpu-16t", "1:e-gpu-16t"   # Server's constructed names


def _mm_stages(d=8, seed=0, n=2):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((d, d)) * 0.2, jnp.float32)

    def mlp(x, w):
        return jnp.maximum(gemm_ref(x, w), 0.0)

    kern = Kernel("mlp", executor=mlp,
                  counts=lambda **kw: gemm_counts(m=d, n=d, k=d))
    return [Stage(kern, consts=(w,), n_inputs=1) for _ in range(n)]


def _eager_ref(stages, x):
    outs, _ = APU(EGPU_16T).offload(stages, (x,), mode="eager")
    return np.asarray(outs[0].data)


# ---------------------------------------------------------------------------
# FaultPlan
# ---------------------------------------------------------------------------
def test_fault_plan_draw_is_deterministic_and_seed_sensitive():
    kw = dict(p_launch_fail=0.3, p_latency_spike=0.5, latency_spike_s=0.1)
    grid = [(lane, i) for lane in ("0:a", "1:b") for i in range(40)]
    a = [FaultPlan(seed=5, **kw).draw(l, i) for l, i in grid]
    b = [FaultPlan(seed=5, **kw).draw(l, i) for l, i in grid]
    assert a == b                        # pure function of (seed, lane, idx)
    c = [FaultPlan(seed=6, **kw).draw(l, i) for l, i in grid]
    assert a != c                        # the seed actually matters
    # decisions differ across lanes too (lane name is part of the key)
    assert ([d for (l, _), d in zip(grid, a) if l == "0:a"]
            != [d for (l, _), d in zip(grid, a) if l == "1:b"])


def test_fault_plan_validates_inputs_and_blackout_covers():
    with pytest.raises(ValueError, match="p_launch_fail"):
        FaultPlan(p_launch_fail=1.5)
    with pytest.raises(ValueError, match="p_latency_spike"):
        FaultPlan(p_latency_spike=-0.1)
    with pytest.raises(ValueError, match="latency_spike_s"):
        FaultPlan(latency_spike_s=-1.0)
    b = Blackout("x", start=3, length=2)
    assert not b.covers("x", 2) and b.covers("x", 3) and b.covers("x", 4)
    assert not b.covers("x", 5) and not b.covers("y", 3)
    # a blackout fires regardless of the seed (deterministic recovery tests)
    for seed in (0, 7, 12345):
        d = FaultPlan(seed=seed, blackouts=(b,)).draw("x", 3)
        assert d.fail and "blackout" in d.reason


# ---------------------------------------------------------------------------
# Rerouting + circuit breaker
# ---------------------------------------------------------------------------
def test_blackout_reroutes_retries_bit_identical():
    """A lane blacked out for its first 4 launches: traffic reroutes to the
    healthy sibling (retries), the offender quarantines and recovers via a
    half-open probe, and EVERY result stays bit-identical to the fault-free
    eager path — nothing is shed."""
    stages = _mm_stages()
    plan = FaultPlan(seed=env_seed(3),
                     blackouts=(Blackout(LANE0, start=0, length=4),))
    srv = Server(stages, workers=(EGPU_16T, EGPU_16T), bucket_sizes=(8,),
                 max_batch=1, fault_plan=plan,
                 breaker_threshold=2, breaker_cooldown=2)
    rng = np.random.default_rng(17)
    rids = []
    for _ in range(8):
        x = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
        rids.append((srv.submit(x), x))
    srv.flush()
    rep = srv.report()
    assert rep.n_shed == 0 and rep.n_dispatch_failures == 0
    assert rep.n_retries >= 1            # failed attempts were rerouted
    assert rep.n_quarantines >= 1        # the breaker tripped at least once
    assert plan.injected_failures == 4   # the whole window was absorbed
    per = {q.name: q for q in rep.queues}
    assert per[LANE0].launch_failures == 4
    # the blacked-out lane RECOVERED: it serves again after the window
    assert per[LANE0].batches >= 1 and per[LANE1].batches >= 1
    assert per[LANE0].breaker_state == "closed"
    for rid, x in rids:                  # bit-identical under retries
        (got,) = srv.result(rid)
        np.testing.assert_array_equal(np.asarray(got), _eager_ref(stages, x))


def test_circuit_breaker_state_machine():
    br = CircuitBreaker(failure_threshold=2, cooldown=3)
    assert br.available(tick=1)
    br.record_failure(1)
    assert br.state == "closed" and br.available(2)   # below threshold
    br.record_failure(2)                 # consecutive hit: trips OPEN
    assert br.state == "open" and br.trips == 1
    assert not br.available(3) and not br.available(4)
    assert br.available(5)               # cooldown elapsed -> HALF-OPEN
    assert br.state == "half-open"
    br.on_attempt()                      # the single probe slot
    assert not br.available(5)           # no second probe while in flight
    br.record_failure(5)                 # probe failed: re-trips, one strike
    assert br.state == "open" and br.trips == 2
    assert br.available(8)               # next half-open window
    br.on_attempt()
    br.record_success()                  # probe succeeded: CLOSED again
    assert br.state == "closed" and br.available(9)
    br.record_failure(9)                 # success reset the consecutive count
    assert br.state == "closed"
    with pytest.raises(ValueError):
        CircuitBreaker(failure_threshold=0)
    with pytest.raises(ValueError):
        CircuitBreaker(cooldown=0)


def test_latency_spike_inflates_modeled_time_not_energy():
    """A spiked launch models slower (scheduling stall) but burns no extra
    energy and never perturbs outputs."""
    stages = _mm_stages()
    x = jnp.asarray(np.random.default_rng(2).standard_normal((8, 8)),
                    jnp.float32)

    def run(plan):
        srv = Server(stages, workers=(EGPU_16T,), bucket_sizes=(8,),
                     max_batch=1, fault_plan=plan)
        rid = srv.submit(x)
        srv.flush()
        (out,) = srv.result(rid)
        return np.asarray(out), srv.report()

    clean_out, clean = run(None)
    spike = FaultPlan(seed=env_seed(5), p_latency_spike=1.0,
                      latency_spike_s=0.25)
    spiked_out, rep = run(spike)
    assert spike.injected_spikes == 1 and spike.injected_failures == 0
    np.testing.assert_array_equal(spiked_out, clean_out)
    assert rep.modeled_latency_s[50] == pytest.approx(
        clean.modeled_latency_s[50] + 0.25, rel=1e-9)
    assert rep.modeled_energy_per_request_j == pytest.approx(
        clean.modeled_energy_per_request_j, rel=1e-9)
    assert rep.n_retries == 0 and rep.n_shed == 0


def test_dispatch_exhaustion_sheds_loudly_then_recovers():
    """Every lane dead: the batch exhausts its retry budget and is shed
    LOUDLY (result() raises AdmissionError, counters tick) — and once the
    blackout windows pass, the very next request serves normally."""
    stages = _mm_stages()
    plan = FaultPlan(blackouts=(Blackout(LANE0, 0, 2), Blackout(LANE1, 0, 2)))
    srv = Server(stages, workers=(EGPU_16T, EGPU_16T), bucket_sizes=(8,),
                 max_batch=1, fault_plan=plan)
    x = jnp.ones((8, 8), jnp.float32)
    rid = srv.submit(x)                  # 4 attempts, all blacked out
    with pytest.raises(AdmissionError, match="shed"):
        srv.result(rid)
    rep = srv.report()
    assert rep.n_dispatch_failures == 1 and rep.n_shed == 1
    assert plan.injected_failures == 4   # 2 attempts x 2 lanes consumed
    # recovery: the windows are spent, the fleet serves again
    rid2 = srv.submit(2.0 * x)
    srv.flush()
    (got,) = srv.result(rid2)
    np.testing.assert_array_equal(np.asarray(got),
                                  _eager_ref(stages, 2.0 * x))
    assert srv.report().n_dispatch_failures == 1    # no new failures


def test_injected_fault_carries_backpressure_retired_tickets():
    """An InjectedFault raised mid-launch must hand back the tickets the
    worker already retired for backpressure — those launches were real and
    the dispatcher finalizes them even on the failure path."""
    stages = _mm_stages()
    # lane 0 fails its 3rd and 4th launches (the single-lane fleet's whole
    # retry budget for one batch), after two clean ones
    plan = FaultPlan(blackouts=(Blackout(LANE0, 2, 2),))
    srv = Server(stages, workers=(EGPU_16T,), bucket_sizes=(8,),
                 max_batch=1, max_in_flight=2, fault_plan=plan)
    (worker,) = srv.dispatcher.workers
    rng = np.random.default_rng(23)
    xs = [jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
          for _ in range(3)]
    r0 = srv.submit(xs[0])
    r1 = srv.submit(xs[1])
    assert worker.depth == 2             # both in flight, window full
    # 3rd launch: backpressure retires r0's ticket FIRST, then the fault
    # fires; the single-lane fleet exhausts retries and sheds r2 — but
    # r0's retired result must survive the failed dispatch
    r2 = srv.submit(xs[2])
    np.testing.assert_array_equal(np.asarray(srv.result(r0)[0]),
                                  _eager_ref(stages, xs[0]))
    with pytest.raises(AdmissionError, match="shed"):
        srv.result(r2)
    srv.flush()
    np.testing.assert_array_equal(np.asarray(srv.result(r1)[0]),
                                  _eager_ref(stages, xs[1]))


def test_injected_fault_exposes_lane_and_launch_index():
    plan = FaultPlan(blackouts=(Blackout("solo", 0, 1),))
    from repro.serve import QueueWorker
    w = QueueWorker(EGPU_16T, name="solo", fault_plan=plan)
    with pytest.raises(InjectedFault) as ei:
        w._fault_gate()
    assert ei.value.lane == "solo" and ei.value.launch_idx == 0
    assert "blackout" in ei.value.reason
    assert w.launch_failures == 1
    assert w._fault_gate() == 0.0        # next launch index is clean


# ---------------------------------------------------------------------------
# Property: none silently lost, bit-identical under any seeded plan
# ---------------------------------------------------------------------------
def _fault_scenario(seed, p_fail, p_spike, spike_s, blackout_len):
    """Drive a 2-lane server through a random seeded FaultPlan and assert
    the two ISSUE-6 invariants:

    (a) every ACCEPTED rid is either result()-able or raises a loud
        AdmissionError — never a silent loss (a KeyError would fail here);
    (b) every produced result — retried, rerouted, or deadline-flushed —
        is bit-identical to the fault-free eager path.
    """
    stages = _mm_stages()
    plan = FaultPlan(seed=seed, p_launch_fail=p_fail,
                     p_latency_spike=p_spike, latency_spike_s=spike_s,
                     blackouts=(Blackout(LANE0, 1, blackout_len),))
    t = [0.0]
    srv = Server(stages, workers=(EGPU_16T, EGPU_16T), bucket_sizes=(8,),
                 max_batch=2, max_pending=8, fault_plan=plan,
                 breaker_threshold=2, breaker_cooldown=2,
                 clock=lambda: t[0])
    rng = np.random.default_rng(seed)
    accepted = []
    for i in range(10):
        x = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
        t[0] += float(rng.random()) * 1e-3
        try:
            accepted.append((srv.submit(x, deadline=10.0, priority=i % 3), x))
        except AdmissionError:
            pass
    srv.flush()
    # one deadline-carrying straggler flushed by the deadline pump (its
    # bucket never fills): must also come back bit-identical
    x_f = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    flushes_before = srv.batcher.deadline_flushes
    rid_f = srv.submit(x_f, deadline=5.0)
    t[0] += 5.0
    srv.tick()
    assert srv.batcher.deadline_flushes == flushes_before + 1
    srv.flush()
    accepted.append((rid_f, x_f))

    n_ok = n_shed = 0
    for rid, x in accepted:
        try:
            (got,) = srv.result(rid)     # KeyError here = silently lost
        except AdmissionError as e:
            assert "shed" in str(e)
            n_shed += 1
            continue
        np.testing.assert_array_equal(np.asarray(got), _eager_ref(stages, x))
        n_ok += 1
    assert n_ok + n_shed == len(accepted)
    rep = srv.report()
    assert rep.n_requests == n_ok
    assert rep.n_shed >= n_shed          # report counts door-sheds too
    if plan.injected_failures:           # faults leave visible footprints
        assert rep.n_retries + rep.n_dispatch_failures >= 1
    return n_ok


@pytest.mark.parametrize("seed,p_fail,p_spike,blackout_len", [
    (env_seed(0), 0.0, 0.0, 0),          # fault-free control
    (env_seed(1), 0.2, 0.3, 2),          # mixed faults
    (env_seed(2), 0.6, 0.0, 4),          # failure-heavy
    (env_seed(3), 0.0, 1.0, 0),          # spike-only
])
def test_no_request_silently_lost_seeded_sweep(seed, p_fail, p_spike,
                                               blackout_len):
    n_ok = _fault_scenario(seed, p_fail, p_spike, 0.05, blackout_len)
    if p_fail == 0.0 and blackout_len == 0:
        assert n_ok == 11                # fault-free: everything completes


def test_no_request_silently_lost_property():
    """Satellite (ISSUE 6): hypothesis sweep over random seeded FaultPlans
    — same invariants as the seeded sweep, adversarial parameters."""
    pytest.importorskip("hypothesis")    # not baked into every image
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           p_fail=st.floats(0.0, 0.8),
           p_spike=st.floats(0.0, 1.0),
           spike_s=st.floats(0.0, 0.5),
           blackout_len=st.integers(0, 5))
    def prop(seed, p_fail, p_spike, spike_s, blackout_len):
        _fault_scenario(seed, p_fail, p_spike, spike_s, blackout_len)

    prop()
