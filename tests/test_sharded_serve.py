"""Sharded serving (ISSUE 5): ShardedWorker mesh lanes under the dispatcher.

Three coverage tiers, because the main test process must keep its real
device layout (see conftest):

* single-device tests — a 1-device mesh is a degenerate but fully wired
  ShardedWorker: placement-keyed cache isolation, divisibility fallback
  and report plumbing all run on any host;
* 2-device in-process tests — skipped unless the interpreter already has
  >= 2 devices (the CI matrix leg with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=2`` exercises them
  on hosted runners);
* a SUBPROCESS test (always runs) — the acceptance pin: the paper's
  TinyBio bucket served through a ShardedWorker on a 2-device mesh is
  bit-identical to the plain QueueWorker path, and a shared GraphCache
  shows zero key collisions between the two.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EGPU_16T, Kernel, Stage
from repro.kernels.gemm.ref import counts as gemm_counts
from repro.kernels.gemm.ref import gemm_ref
from repro.serve import (GraphCache, QueueWorker, Server, ShardedWorker,
                         data_mesh, shard_breakdown)

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >= 2 devices (CI matrix leg forces 2 host devices)")


def _mm_stages(d=8, seed=0, n=2):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((d, d)) * 0.2, jnp.float32)

    def mlp(x, w):
        return jnp.maximum(gemm_ref(x, w), 0.0)

    kern = Kernel("mlp", executor=mlp,
                  counts=lambda **kw: gemm_counts(m=d, n=d, k=d))
    return [Stage(kern, consts=(w,), n_inputs=1) for _ in range(n)]


def _requests(n, d=8, seed=5):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((int(rng.integers(3, d + 1)), d)),
                        jnp.float32) for _ in range(n)]


# ---------------------------------------------------------------------------
# Single-device coverage (1-device mesh: degenerate but fully wired)
# ---------------------------------------------------------------------------
def test_one_device_mesh_serves_and_reports():
    stages = _mm_stages()
    worker = ShardedWorker(EGPU_16T, data_mesh(1), name="mesh1")
    srv = Server(stages, workers=(worker,), bucket_sizes=(8,), max_batch=2)
    xs = _requests(4)
    rids = [srv.submit(x) for x in xs]
    srv.flush()
    for rid, x in zip(rids, xs):
        (out,) = srv.result(rid)
        assert out.shape == x.shape
    rep = srv.report()
    (qs,) = rep.queues
    assert qs.shards == 1
    assert qs.mesh_axes == (("data", 1),)
    # a 1-device axis is always fully utilized (factor 1 of size 1)
    assert dict(qs.mesh_utilization) == {"data": 1.0}
    assert rep.mesh_utilization == {"data": 1.0}
    assert "mesh data=1" in rep.summary()


def test_sharded_and_plain_cache_entries_never_collide():
    """Same pipeline, same bucket, shared cache: the sharded worker's
    placement must key a SEPARATE entry (zero collisions both ways)."""
    stages = _mm_stages()
    cache = GraphCache(capacity=8)
    plain = QueueWorker(EGPU_16T, name="plain")
    sharded = ShardedWorker(EGPU_16T, data_mesh(1), name="mesh")
    for srv_workers in ((plain,), (sharded,)):
        srv = Server(stages, workers=srv_workers, bucket_sizes=(8,),
                     max_batch=2)
        srv.cache = cache
        for x in _requests(2):
            srv.submit(x)
        srv.flush()
    assert cache.misses == 2 and len(cache) == 2
    # warm replays hit their own entries
    for srv_workers in ((plain,), (sharded,)):
        srv = Server(stages, workers=srv_workers, bucket_sizes=(8,),
                     max_batch=2)
        srv.cache = cache
        for x in _requests(2):
            srv.submit(x)
        srv.flush()
    assert cache.misses == 2 and cache.hits >= 2


def test_placement_distinguishes_mesh_and_rules():
    w1 = ShardedWorker(EGPU_16T, data_mesh(1), name="a")
    w2 = ShardedWorker(EGPU_16T, data_mesh(1), name="b")
    assert w1.apu.placement == w2.apu.placement    # same mesh layout: share
    from repro.distributed.sharding import SERVE_RULES
    w3 = ShardedWorker(EGPU_16T, data_mesh(1), name="c",
                       rules=SERVE_RULES.with_seq_sharding(True))
    assert w3.apu.placement != w1.apu.placement
    assert QueueWorker(EGPU_16T, name="d").apu.placement is None


def test_shard_breakdown_scales_only_work_phases():
    from repro.core.machine import PhaseBreakdown
    pb = PhaseBreakdown(startup=100.0, scheduling=50.0, transfer=40.0,
                        compute=200.0, freq_hz=1e6)
    sb = shard_breakdown(pb, 2)
    assert sb.startup == 100.0 and sb.scheduling == 50.0
    assert sb.transfer == 20.0 and sb.compute == 100.0
    assert shard_breakdown(pb, 1) is pb


def test_sharded_worker_rejects_bad_mesh():
    with pytest.raises(TypeError):
        ShardedWorker(EGPU_16T, mesh="not-a-mesh")
    with pytest.raises(ValueError):
        data_mesh(0)
    with pytest.raises(ValueError):
        data_mesh(len(jax.devices()) + 1)


# ---------------------------------------------------------------------------
# >= 2 devices in-process (the CI 2-device matrix leg runs these)
# ---------------------------------------------------------------------------
@multi_device
def test_two_shard_results_bit_identical_and_modeled_scaled():
    stages = _mm_stages(n=3)
    xs = _requests(8)
    outs, modeled = {}, {}
    for key, worker in (("plain", QueueWorker(EGPU_16T, name="p")),
                        ("sharded", ShardedWorker(EGPU_16T, data_mesh(2),
                                                  name="s"))):
        srv = Server(stages, workers=(worker,), bucket_sizes=(8,),
                     max_batch=2)
        rids = [srv.submit(x) for x in xs]
        srv.flush()
        outs[key] = [np.asarray(srv.result(r)[0]) for r in rids]
        modeled[key] = srv.report().queues[0].modeled_s
    for a, b in zip(outs["plain"], outs["sharded"]):
        np.testing.assert_array_equal(a, b)
    # transfer+compute halve, startup+scheduling don't: strictly between
    assert modeled["sharded"] < modeled["plain"]
    assert modeled["sharded"] > modeled["plain"] / 2


@multi_device
def test_divisibility_fallback_replicates_odd_capacity():
    """max_batch=3 on a 2-shard data axis: 3 % 2 != 0, so the batch axis
    must fall back to replication (shards=1, full results, honest
    utilization < 1) instead of failing to lower."""
    stages = _mm_stages()
    worker = ShardedWorker(EGPU_16T, data_mesh(2), name="odd")
    srv = Server(stages, workers=(worker,), bucket_sizes=(8,), max_batch=3)
    xs = _requests(3)
    rids = [srv.submit(x) for x in xs]
    srv.flush()
    for rid, x in zip(rids, xs):
        (out,) = srv.result(rid)
        assert out.shape == x.shape
    (qs,) = srv.report().queues
    assert qs.shards == 2                    # the lane still spans 2 devices
    assert dict(qs.mesh_utilization)["data"] == pytest.approx(0.5)
    assert srv.report().mesh_utilization["data"] == pytest.approx(0.5)


@multi_device
def test_dispatcher_routes_mixed_plain_and_sharded_lanes():
    stages = _mm_stages()
    plain = QueueWorker(EGPU_16T, name="plain")
    sharded = ShardedWorker(EGPU_16T, data_mesh(2), name="mesh2")
    srv = Server(stages, workers=(plain, sharded), bucket_sizes=(8,),
                 max_batch=2, max_in_flight=2)
    for x in _requests(20):
        srv.submit(x)
    srv.flush()
    rep = srv.report()
    per = {q.name: q for q in rep.queues}
    assert per["plain"].batches + per["mesh2"].batches == 10
    # both lanes bootstrap; after that the sharded lane's lower modeled
    # seconds-per-request wins depth ties, attracting more traffic
    assert per["mesh2"].batches > per["plain"].batches
    assert per["plain"].batches >= 1
    assert rep.mesh_utilization == {"data": 1.0}


@multi_device
def test_const_axes_shard_model_parallel_stage_args():
    """A constant tagged with a divisible logical axis lands on 'model'."""
    d = 8
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((d, d)) * 0.2, jnp.float32)

    def mlp(x, w):
        return jnp.maximum(gemm_ref(x, w), 0.0)

    stages = [Stage(Kernel("mlp", executor=mlp,
                           counts=lambda **kw: gemm_counts(m=d, n=d, k=d)),
                    consts=(w,), n_inputs=1)]
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    worker = ShardedWorker(EGPU_16T, mesh, name="mp",
                           const_axes=((None, "mlp"),))
    srv = Server(stages, workers=(worker,), bucket_sizes=(8,), max_batch=2)
    xs = _requests(2)
    rids = [srv.submit(x) for x in xs]
    srv.flush()
    ref = Server(stages, workers=(EGPU_16T,), bucket_sizes=(8,), max_batch=2)
    rids_ref = [ref.submit(x) for x in xs]
    ref.flush()
    for rs, rr in zip(rids, rids_ref):
        np.testing.assert_array_equal(np.asarray(srv.result(rs)[0]),
                                      np.asarray(ref.result(rr)[0]))
    # the model-parallel const registers on the "model" axis: utilization
    # distinguishes a healthy MP lane (100%) from a replication fallback
    (qs,) = srv.report().queues
    assert dict(qs.mesh_utilization)["model"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Acceptance pin (always runs): TinyBio, 2-device mesh, subprocess
# ---------------------------------------------------------------------------
SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.tinybio import synth_signal, tinybio_stages
from repro.core import EGPU_16T
from repro.serve import (GraphCache, QueueWorker, Server, ShardedWorker,
                         data_mesh)

assert len(jax.devices()) == 2, jax.devices()
stages, _ = tinybio_stages(EGPU_16T)
n = 65_536
sigs = [jnp.asarray(synth_signal(n, seed=s)) for s in (3, 4)]
cache = GraphCache(capacity=8)

def serve(worker):
    srv = Server(stages, workers=(worker,), bucket_sizes=(n,), max_batch=2)
    srv.cache = cache
    rids = [srv.submit(s) for s in sigs]
    srv.flush()
    return [tuple(np.asarray(o) for o in srv.result(r)) for r in rids], srv

plain, _ = serve(QueueWorker(EGPU_16T, name="single"))
sharded, srv = serve(ShardedWorker(EGPU_16T, data_mesh(2), name="mesh"))

identical = all(
    len(a) == len(b) and all(np.array_equal(x, y) for x, y in zip(a, b))
    for a, b in zip(plain, sharded))
qs = srv.report().queues[0]
print(json.dumps({
    "identical": identical,
    "cache": cache.stats(),
    "shards": qs.shards,
    "util": dict(qs.mesh_utilization),
}))
"""


def test_tinybio_sharded_bit_identical_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)           # the script sets its own
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-4000:]
    result = json.loads(proc.stdout.strip().splitlines()[-1])
    # bit-identical through the sharded lane
    assert result["identical"]
    # one entry per worker in the SHARED cache: zero key collisions (a
    # collision would read as 1 miss + 1 hit), nothing evicted
    assert result["cache"]["misses"] == 2
    assert result["cache"]["hits"] == 0
    assert result["cache"]["evictions"] == 0
    # the full TinyBio bucket (batch 2 over data=2) genuinely sharded
    assert result["shards"] == 2
    assert result["util"] == {"data": 1.0}


# ---------------------------------------------------------------------------
# Fault injection through a sharded lane (ISSUE 6)
# ---------------------------------------------------------------------------
def test_sharded_lane_blackout_reroutes_bit_identical():
    """The fault gate fires inside ShardedWorker._do_launch too: a
    blacked-out mesh lane reroutes its micro-batches to the plain sibling,
    results stay bit-identical, and the lane serves again after the
    window."""
    from repro.serve import Blackout, FaultPlan, env_seed
    stages = _mm_stages()
    plan = FaultPlan(seed=env_seed(11),
                     blackouts=(Blackout("mesh", start=0, length=2),))
    mesh_lane = ShardedWorker(EGPU_16T, data_mesh(1), name="mesh",
                              fault_plan=plan)
    plain_lane = QueueWorker(EGPU_16T, name="plain", fault_plan=plan)
    srv = Server(stages, workers=(mesh_lane, plain_lane), bucket_sizes=(8,),
                 max_batch=2, breaker_threshold=2, breaker_cooldown=1)
    xs = _requests(12)
    rids = [srv.submit(x) for x in xs]
    srv.flush()
    rep = srv.report()
    assert rep.n_shed == 0 and rep.n_dispatch_failures == 0
    assert rep.n_retries >= 1
    per = {q.name: q for q in rep.queues}
    assert per["mesh"].launch_failures == 2
    assert per["mesh"].batches >= 1          # recovered after the window
    assert per["plain"].batches >= 1
    ref = Server(stages, workers=(EGPU_16T,), bucket_sizes=(8,), max_batch=2)
    rids_ref = [ref.submit(x) for x in xs]
    ref.flush()
    for rs, rr in zip(rids, rids_ref):
        np.testing.assert_array_equal(np.asarray(srv.result(rs)[0]),
                                      np.asarray(ref.result(rr)[0]))
