"""Straggler mitigation: backup dispatch fires, wins, and matches exactly."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.straggler import BackupStepRunner


def _step(x, w):
    return x @ w + 1.0


def test_no_backups_when_healthy():
    runner = BackupStepRunner(jax.jit(_step), threshold=50.0)
    x, w = jnp.ones((32, 32)), jnp.eye(32)
    for _ in range(5):
        out = runner(x, w)
    assert runner.stats.steps == 5
    assert runner.stats.backups_fired == 0
    runner.close()


def test_backup_fires_and_result_is_identical():
    # step 3's primary dispatch straggles for 2 s; EMA is ~ms scale
    delays = {3: 2.0}
    runner = BackupStepRunner(jax.jit(_step), threshold=3.0,
                              warmup_steps=2,
                              delay_hook=lambda s: delays.get(s, 0.0))
    x = jnp.asarray(np.random.default_rng(0).standard_normal((64, 64)),
                    jnp.float32)
    w = jnp.asarray(np.random.default_rng(1).standard_normal((64, 64)),
                    jnp.float32)
    gold = np.asarray(_step(x, w))
    outs = [runner(x, w) for _ in range(5)]
    for o in outs:
        np.testing.assert_allclose(np.asarray(o), gold, rtol=1e-6)
    assert runner.stats.backups_fired >= 1
    assert runner.stats.backups_won >= 1       # backup beats a 2 s straggle
    runner.close()
