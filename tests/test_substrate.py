"""Substrate tests: optimizer, schedules, data, checkpoint, compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")      # not baked into every image
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint import (CheckpointManager, load_checkpoint,
                              save_checkpoint)
from repro.data import DataConfig, SyntheticLMData
from repro.distributed.compression import compress_int8, decompress_int8
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_schedule, wsd_schedule)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def _run_quadratic(moment_dtype, steps=150):
    params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(weight_decay=0.0, moment_dtype=moment_dtype)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(steps):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, 0.05, cfg)
    return float(loss(params))


def test_adamw_converges():
    assert _run_quadratic(jnp.bfloat16) < 1e-3


def test_bf16_moments_match_fp32_convergence():
    """The memory-saving bf16 moments must not change convergence class."""
    l_bf16 = _run_quadratic(jnp.bfloat16)
    l_f32 = _run_quadratic(jnp.float32)
    assert l_bf16 < 10 * max(l_f32, 1e-9) + 1e-6


def test_grad_clipping_bounds_update():
    params = {"w": jnp.asarray([0.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(clip_norm=1.0, weight_decay=0.0)
    g = {"w": jnp.asarray([1e6])}
    _, _, metrics = adamw_update(params, g, state, 1e-3, cfg)
    assert float(metrics["clip_scale"]) < 1e-5
    assert float(metrics["grad_norm"]) == pytest.approx(1e6, rel=1e-3)


def test_weight_decay_only_on_matrices():
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    state = adamw_init(params)
    g = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
    new, _, _ = adamw_update(params, g, state, 0.1,
                             AdamWConfig(weight_decay=0.1))
    assert float(new["w"][0, 0]) < 1.0       # decayed
    assert float(new["b"][0]) == pytest.approx(1.0)  # not decayed


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------
def test_wsd_schedule_shape():
    sched = wsd_schedule(1.0, 1000, warmup_steps=100, decay_frac=0.2)
    assert float(sched(jnp.int32(0))) == 0.0
    assert float(sched(jnp.int32(100))) == pytest.approx(1.0)
    assert float(sched(jnp.int32(500))) == pytest.approx(1.0)   # stable
    assert float(sched(jnp.int32(999))) < 0.15                  # decayed
    # monotone decay in the last phase
    tail = [float(sched(jnp.int32(s))) for s in range(800, 1000, 25)]
    assert all(a >= b for a, b in zip(tail, tail[1:]))


def test_cosine_schedule_endpoints():
    sched = cosine_schedule(2.0, 100, warmup_steps=10, final_scale=0.1)
    assert float(sched(jnp.int32(10))) == pytest.approx(2.0)
    assert float(sched(jnp.int32(100))) == pytest.approx(0.2, rel=1e-2)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------
def test_data_exact_replay():
    d = SyntheticLMData(DataConfig(4, 64, 101, seed=7))
    a, b = d.batch_at(13), d.batch_at(13)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.batch_at(14)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_labels_are_shifted_tokens():
    d = SyntheticLMData(DataConfig(2, 32, 101, seed=0))
    b = d.batch_at(0)
    # labels[t] is the next token of tokens[t] in the same stream
    assert b["tokens"].shape == b["labels"].shape == (2, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_data_is_learnable_structure():
    """75 % of transitions are the deterministic successor — a model can
    beat the uniform baseline (this is what examples/train_lm.py exploits)."""
    d = SyntheticLMData(DataConfig(8, 512, 64, seed=3))
    b = d.batch_at(0)
    toks, labels = b["tokens"], b["labels"]
    # the successor function is per-sequence (keyed), so measure the
    # majority-successor agreement within each row: with 75 % deterministic
    # transitions the dominant next-token share must be well above uniform
    agree = []
    for row_t, row_l in zip(toks, labels):
        pair_counts = {}
        for t, l in zip(row_t, row_l):
            pair_counts.setdefault(int(t), []).append(int(l))
        agree += [np.bincount(v).max() / len(v)
                  for v in pair_counts.values() if len(v) >= 4]
    assert np.mean(agree) > 0.5, np.mean(agree)


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10), "n": {"w": jnp.ones((3, 4)) * 2.5}}
    save_checkpoint(str(tmp_path / "ck"), tree, step=5, meta={"x": 1})
    out, man = load_checkpoint(str(tmp_path / "ck"), like=tree)
    assert man["step"] == 5 and man["meta"]["x"] == 1
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["n"]["w"], tree["n"]["w"])


def test_checkpoint_atomic_overwrite(tmp_path):
    tree = {"a": jnp.zeros(4)}
    p = str(tmp_path / "ck")
    save_checkpoint(p, tree, step=1)
    save_checkpoint(p, {"a": jnp.ones(4)}, step=2)
    out, man = load_checkpoint(p, like=tree)
    assert man["step"] == 2
    np.testing.assert_array_equal(out["a"], np.ones(4))
    assert not os.path.exists(p + ".tmp")


def test_manager_rotation_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(3)}
    for s in (10, 20, 30, 40):
        mgr.save_async(tree, s)
    mgr.wait()
    assert mgr.all_steps() == [30, 40]
    assert mgr.latest_step() == 40
    out, man = mgr.restore_latest(like=tree)
    assert man["step"] == 40


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------
@settings(deadline=None, max_examples=25)
@given(scale=st.floats(1e-3, 1e3))
def test_int8_codec_error_bound(scale):
    g = jnp.asarray(np.random.default_rng(0).standard_normal(128) * scale,
                    jnp.float32)
    q, s, err = compress_int8(g)
    rec = decompress_int8(q, s)
    # per-element error bounded by half a quantization step
    assert float(jnp.max(jnp.abs(rec + err - g))) < 1e-5
    assert float(jnp.max(jnp.abs(rec - g))) <= float(s) * 0.5 + 1e-6


def test_error_feedback_telescopes():
    """Sum of decompressed grads + final residual == sum of true grads."""
    rng = np.random.default_rng(1)
    total_true = np.zeros(32, np.float32)
    total_sent = np.zeros(32, np.float32)
    err = jnp.zeros(32)
    for _ in range(50):
        g = jnp.asarray(rng.standard_normal(32).astype(np.float32))
        q, s, err = compress_int8(g, err)
        total_true += np.asarray(g)
        total_sent += np.asarray(decompress_int8(q, s))
    resid = np.asarray(err)
    np.testing.assert_allclose(total_sent + resid, total_true,
                               rtol=1e-4, atol=1e-4)


def test_compression_wire_bytes_4x():
    g = jnp.zeros(1024, jnp.float32)
    q, s, _ = compress_int8(g)
    assert q.dtype == jnp.int8
    assert q.nbytes * 4 == g.nbytes
