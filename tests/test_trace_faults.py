"""Trace completeness under injected faults (ISSUE 7 satellite).

The span-tree contract must hold under adversity, not just on the happy
path: for ANY seeded :class:`~repro.serve.faults.FaultPlan` — launch
failures, latency spikes, lane blackouts, retry storms, breaker trips,
dispatch exhaustion — every accepted rid's trace ends in exactly one
terminal span (``result`` or a named ``shed``), retries and breaker trips
are recorded as span events, and no tree is ever left dangling.  A seeded
parametrized sweep runs everywhere; the hypothesis property sweep rides
where the package is available (CI installs it).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EGPU_16T, Kernel, Stage
from repro.kernels.gemm.ref import counts as gemm_counts
from repro.kernels.gemm.ref import gemm_ref
from repro.obs import TERMINAL_SPANS, Tracer, validate_chrome_trace
from repro.serve import (AdmissionError, Blackout, FaultPlan, Server,
                        env_seed)

LANE0 = "0:e-gpu-16t"


def _mm_stages(d=8, seed=0, n=2):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((d, d)) * 0.2, jnp.float32)

    def mlp(x, w):
        return jnp.maximum(gemm_ref(x, w), 0.0)

    kern = Kernel("mlp", executor=mlp,
                  counts=lambda **kw: gemm_counts(m=d, n=d, k=d))
    return [Stage(kern, consts=(w,), n_inputs=1) for _ in range(n)]


def _traced_fault_scenario(seed, p_fail, p_spike, spike_s, blackout_len):
    """Drive a traced 2-lane server through a seeded FaultPlan and assert
    the ISSUE-7 completeness contract on the resulting span forest."""
    stages = _mm_stages()
    plan = FaultPlan(seed=seed, p_launch_fail=p_fail,
                     p_latency_spike=p_spike, latency_spike_s=spike_s,
                     blackouts=(Blackout(LANE0, 1, blackout_len),))
    t = [0.0]
    tracer = Tracer()
    srv = Server(stages, workers=(EGPU_16T, EGPU_16T), bucket_sizes=(8,),
                 max_batch=2, max_pending=8, fault_plan=plan,
                 breaker_threshold=2, breaker_cooldown=2,
                 clock=lambda: t[0], tracer=tracer)
    rng = np.random.default_rng(seed)
    accepted = []
    for i in range(12):
        x = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
        t[0] += float(rng.random()) * 1e-3
        try:
            accepted.append(srv.submit(x, deadline=10.0, priority=i % 3))
        except AdmissionError:
            pass                         # door-shed: never got a rid
    srv.flush()

    # completeness: every ACCEPTED rid grew a tree, every tree is closed
    # with exactly one terminal — never dangling, even mid-blackout
    assert tracer.request_rids() == sorted(accepted)
    assert tracer.validate_request_trees() == []
    n_result = n_shed = 0
    for rid in accepted:
        root = tracer.request_root(rid)
        terminals = [s for s in tracer.children(root)
                     if s.name in TERMINAL_SPANS]
        assert len(terminals) == 1
        if terminals[0].name == "result":
            n_result += 1
        else:
            n_shed += 1
            assert terminals[0].attrs.get("reason")   # sheds carry a why
    rep = srv.report()
    assert n_result == rep.n_requests
    assert n_shed <= rep.n_shed          # report counts door-sheds too

    # mid-flight adversity leaves span-event footprints on the roots
    events = [name for rid in accepted
              for (_, name, _) in tracer.request_root(rid).events]
    if rep.n_retries:
        assert events.count("retry") >= 1
        assert events.count("fault") >= rep.n_retries
    if rep.n_quarantines:
        assert "breaker-trip" in events
    # and the export still schema-validates
    assert validate_chrome_trace(tracer.to_chrome_json()) == []
    return n_result, n_shed


@pytest.mark.parametrize("seed,p_fail,p_spike,blackout_len", [
    (env_seed(10), 0.0, 0.0, 0),         # fault-free control
    (env_seed(11), 0.2, 0.3, 2),         # mixed faults
    (env_seed(12), 0.6, 0.0, 4),         # failure-heavy + long blackout
    (env_seed(13), 0.0, 1.0, 0),         # spike-only
])
def test_every_accepted_rid_ends_in_one_terminal_seeded(seed, p_fail,
                                                        p_spike,
                                                        blackout_len):
    n_result, n_shed = _traced_fault_scenario(seed, p_fail, p_spike, 0.05,
                                              blackout_len)
    if p_fail == 0.0 and blackout_len == 0:
        assert n_shed == 0               # fault-free: nothing shed


def test_trace_terminates_under_any_fault_plan_property():
    """Hypothesis sweep (ISSUE 7 satellite): the completeness contract
    holds for adversarially-chosen FaultPlan parameters."""
    pytest.importorskip("hypothesis")    # not baked into every image
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           p_fail=st.floats(0.0, 0.8),
           p_spike=st.floats(0.0, 1.0),
           spike_s=st.floats(0.0, 0.5),
           blackout_len=st.integers(0, 5))
    def prop(seed, p_fail, p_spike, spike_s, blackout_len):
        _traced_fault_scenario(seed, p_fail, p_spike, spike_s, blackout_len)

    prop()
